"""Batched serving engine with continuous batching over fixed decode slots.

Design (vLLM-style, adapted to JAX's static shapes):

  * A fixed pool of ``max_slots`` decode slots shares one (B, S, ...) decode
    state (KV caches / SSM states).  All compiled shapes are static.
  * **Admission**: every queued request that fits a free slot is admitted in
    ONE batch — the prompts (minus their last tokens) right-pad to the
    group max rounded to ``prefill_pad`` and prefill in a single
    ``(n_free, pad)`` call (a handful of compiled prefill shapes, not one
    dispatch per request).  Each row tree-inserts into its slot; the next
    decode step replays the last prompt token at ``pos = len-1`` — that both
    yields the first sampled token *and* overwrites the pad garbage at that
    position.  Pad positions beyond ``pos`` are masked by the per-slot
    ``kv_valid``.
  * **Decode (the fast path, DESIGN.md §2/§8)**: all active slots advance in
    one jitted step with a *vector* of per-slot positions.  The step is
    compiled with ``donate_argnums`` on the state, so the KV caches update
    in place instead of being copied every token ("zero-copy").  Sampling
    runs on-device inside the same jit (PRNG key carried through), so the
    per-step host transfer is one int32 per slot — never the (B, V) logits.
  * **Completion**: a slot frees on EOS/max_tokens and is immediately
    refilled from the queue (continuous batching).

Weights may be float or SigmaQuant-packed ``QuantizedTensor`` leaves
(quant.apply.quantize_for_serve).  Packed Q/K/V and gate/up groups of equal
bitwidth are fused at admission time into single packed buffers
(quant.apply.fuse_projections) so each decode step launches one kernel per
group; decode is memory-bound on HBM weight bytes, which is exactly where
per-layer bitwidth pays (DESIGN.md §2).

The decode state itself may be quantized (DESIGN.md §11): ``state_bits``
(or a ``PolicyArtifact`` carrying a searched state policy) packs the KV
caches as ``kvcache.QuantizedKVLayer`` containers — int lanes + per-block
scales, heterogeneous per-layer K/V bitwidths — and the engine verifies the
built state against the artifact exactly like it verifies the packed
weights.  Admission quantizes the prefill rows into their slots; each
decode step requantizes only the sequence block it writes.

With ``paged=True`` (or a v3 artifact carrying pool geometry) the quantized
caches become block pools with per-slot block tables (DESIGN.md §12):
admission maps blocks on demand — sharing bit-identical shared-prefix
blocks by refcount — decode appends allocate at block boundaries against
admission-time growth reservations, a shared block copies on first write
(copy-on-write), and completion frees every mapped block, so the budgeted
``state_bytes`` pays for *live* tokens instead of ``max_slots * max_seq``.
Requests the pool cannot cover yet wait in the queue (backpressure).

Padded prefill is exact for every family: attention masks pad positions via
the per-slot ``kv_valid``, and SSM/hybrid prefills mask pad tokens out of
the recurrent-state update (``lengths`` threaded through ``api.prefill``),
so the decode state never depends on the pad length.

``speculate=K`` (with a ``draft_policy``, or auto-enabled by a v4 artifact
carrying one) turns each decode round into a self-speculative burst
(DESIGN.md §13): a strictly-cheaper re-packing of the SAME weights
proposes K tokens, the deployed policy verifies all K+1 positions in one
batched weight pass, and the cache rewinds bitwise-exactly to the accepted
prefix — greedy output is token-identical to the non-speculative engine on
fp, quantized and paged caches, at up to K+1 tokens per full weight read.

Every request runs a full lifecycle (DESIGN.md §14, serve/lifecycle.py):
QUEUED -> PREFILL -> DECODE -> DONE | FAILED | CANCELLED | TIMED_OUT, with
per-request deadlines/TTFT budgets, explicit ``cancel(uid)``, and
finalize-exactly-once resource accounting.  Under pool pressure the engine
degrades through a tiered shed ladder (speculation K -> smaller K -> off,
releasing burst-headroom reservations; then priority-gated preemption that
snapshots a victim's progress back into the queue) instead of waiting
indefinitely.  Non-finite logits are detected per slot INSIDE the fused
decode/speculate dispatch and quarantine only the offending request; in
speculate mode a poisoned draft falls back to the verify (non-speculative)
path for that slot before anything is failed.  A ``FailureInjector``
drives the same paths offline and ``debug_invariants=True`` re-checks pool
refcount conservation, reservation accounting, and zero-beyond-write after
every loop turn.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import kvcache
from repro.configs.base import ArchConfig
from repro.core.policy import PolicyArtifact
from repro.models import registry
from repro.obs import calibration as obs_calibration
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.quant import apply as qapply
from repro.runtime.resilience import (FailureInjector, SimulatedFailure,
                                      StepTimer, StragglerMonitor)
from repro.spec import loop as spec_loop
from repro.spec.draft import build_draft_params
from .lifecycle import (LifecycleError, RequestLifecycle, RequestState,
                        ShedPolicy, spec_ladder)
from .sampling import sample
from .scheduler import ChunkScheduler, SchedulerConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never stop early
    priority: int = 0             # higher admits first / preempts lower
    deadline_s: float | None = None      # end-to-end budget from submission
    ttft_budget_s: float | None = None   # first-token budget from submission


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0                  # next write position (chunked prefill:
                                  # head tokens prefilled so far)
    generated: list[int] = dataclasses.field(default_factory=list)
    #: monotonic time of the last committed token (inter-token latency)
    last_token_t: float | None = None
    #: mid-chunked-prefill: the slot holds a request whose prompt is still
    #: being prefilled in budgeted chunks (DESIGN.md §17); excluded from the
    #: decode dispatch and (paged) its device table row is masked to -1
    prefilling: bool = False

    @property
    def free(self) -> bool:
        return self.req is None


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


#: integer counters the legacy ``stats()`` view exposes (wall_s rides as a
#: float counter next to these)
_COUNTER_KEYS = ("prefill_tokens", "prefill_chunks", "decode_steps",
                 "loop_turns", "completed",
                 "spec_steps", "spec_proposed", "spec_accepted", "preemptions",
                 "failed", "cancelled", "timed_out", "nan_quarantined",
                 "nan_draft_fallbacks")

#: step-phase span names in serve-loop order (DESIGN.md §16); ``hook`` only
#: appears when a ``step_hook`` is installed, ``prefill_chunk`` only under
#: chunked prefill (DESIGN.md §17)
_PHASE_NAMES = ("hook", "reap", "admission", "prefill_chunk", "prep",
                "dispatch", "device_sync", "commit", "bookkeeping")


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: dict, *, max_slots: int = 4,
                 max_seq: int = 256, prefill_pad: int = 32, qimpl: str = "auto",
                 temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
                 seed: int = 0, state_dtype=jnp.float32,
                 batch_admission: bool = True, fuse_projections: bool = True,
                 state_bits=None, kv_block: int | None = None,
                 paged: bool = False, pool_blocks: int | None = None,
                 share_prefix: bool = True,
                 speculate: int | None = None, draft_policy=None,
                 artifact: PolicyArtifact | None = None,
                 shed: ShedPolicy | None = ShedPolicy(),
                 fault_injector: FailureInjector | None = None,
                 debug_invariants: bool = False,
                 prefill_chunk: int | None = None,
                 step_token_budget: int | None = None):
        if cfg.family in ("audio", "encdec"):
            raise NotImplementedError(
                "enc-dec serving goes through registry.prefill/decode_step directly "
                "(cross-attention KV needs the frames input at admission)")
        self.cfg = cfg
        self._injector = fault_injector
        self._debug_invariants = debug_invariants
        # the searched policy this engine claims to serve: refuse to start if
        # the packed leaf bitwidths disagree with the artifact (the end of the
        # search -> artifact -> packed deployment pipeline, DESIGN.md §10)
        self.artifact = artifact
        self.packed_bits = qapply.packed_policy_bits(params)
        if artifact is not None:
            if self._fault("artifact_mismatch", step=0):
                # drive the real verification path with tampered bits so the
                # deploy-time refusal (not a bypassable shim) is what fires
                name = next(iter(self.packed_bits), None)
                bad = dict(self.packed_bits)
                if name is not None:
                    bad[name] = -1
                raise ValueError(
                    f"packed leaf bitwidths disagree with the policy artifact "
                    f"(injected artifact_mismatch fault): {name}={bad.get(name)}")
            qapply.verify_packed_bits(params, artifact)
        # fuse packed Q/K/V + gate/up groups: one kernel launch per group on
        # the decode fast path; exact-output-preserving (no requantization)
        self.params = qapply.fuse_projections(params) if fuse_projections else params
        self.api = registry.get_api(cfg)
        # self-speculative decoding (DESIGN.md §13): a searched low-bit draft
        # re-packing of the SAME weights proposes K tokens per step; explicit
        # speculate/draft_policy win, else a draft-carrying v4 artifact
        # auto-enables speculation at its searched K
        explicit_draft = draft_policy is not None
        if draft_policy is None and artifact is not None \
                and artifact.draft_policy is not None:
            draft_policy = artifact.draft_policy
            if speculate is None:
                speculate = artifact.draft_k
        if explicit_draft and speculate is None:
            # symmetric with the speculate-without-draft error below: a
            # draft that silently never drafts is a misconfiguration
            raise ValueError("draft_policy given without speculate=K "
                             "(pass speculate, or deploy a v4 artifact "
                             "that records K)")
        self.speculate = int(speculate or 0)
        self.draft_params = None
        self.draft_bits: dict[str, int] = {}
        if self.speculate:
            if draft_policy is None:
                raise ValueError("speculate=K needs a draft_policy (or a "
                                 "draft-carrying v4 artifact)")
            if self.api.decode_verify is None:
                raise NotImplementedError(
                    f"family {cfg.family!r} cannot self-speculate: its decode "
                    f"state has no burst-rewindable KV form (DESIGN.md §13)")
            # draft containers derive from the UNFUSED tree so a heterogeneous
            # draft policy never has to split a fused leaf; equal-bit draft
            # groups re-fuse below exactly like the deployed weights
            draft, self.draft_bits = build_draft_params(params, draft_policy, cfg)
            self.draft_params = (qapply.fuse_projections(draft)
                                 if fuse_projections else draft)
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.prefill_pad = prefill_pad
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.batch_admission = batch_admission
        self._key = jax.random.key(seed)
        self.slots = [_Slot() for _ in range(max_slots)]
        # chunked-prefill continuous batching (DESIGN.md §17): prompts admit
        # in the PREFILLING state and prefill in <= prefill_chunk pieces
        # interleaved with decode turns under a per-step token budget
        self.prefill_chunk = prefill_chunk
        if prefill_chunk is not None:
            if step_token_budget is None:
                # tightest legal budget: a full decode house plus one chunk
                step_token_budget = max_slots + prefill_chunk
            self._scheduler = ChunkScheduler(
                SchedulerConfig(prefill_chunk, step_token_budget), max_slots)
        else:
            if step_token_budget is not None:
                raise ValueError("step_token_budget has no meaning without "
                                 "prefill_chunk (chunked prefill disabled)")
            self._scheduler = None
        #: slot -> per-layer fp K/V scratch carried across chunks (decoder
        #: families); quantization into the live cache happens ONCE at the
        #: final insert, so the cache bytes match the unchunked path
        self._scratch: dict[int, Any] = {}
        #: slot -> padded head tokens (1, S_scr) kept for the whole prefill:
        #: the SSM/hybrid prefix-recompute fallback re-prefills them each
        #: chunk (lengths-masked), decoder finals index them for insertion
        self._chunk_head: dict[int, np.ndarray] = {}
        #: streaming front-end: uid -> on_token callback, plus the poll()
        #: ring of (uid, token) committed since the last drain
        self._on_token: dict[int, Any] = {}
        self._token_events: collections.deque = collections.deque(maxlen=65536)
        # quantized decode state (DESIGN.md §11): explicit state_bits wins,
        # else a searched state policy rides in on the artifact
        if state_bits is None and artifact is not None:
            state_bits = artifact.state_policy
        resolved = (kvcache.resolve_state_bits(state_bits, cfg)
                    if state_bits is not None else None)
        # paged block pool (DESIGN.md §12): explicit paged=True, or an
        # artifact carrying v3 pool geometry
        if artifact is not None and artifact.pool is not None:
            paged = True
            pool_blocks = pool_blocks or int(artifact.pool["num_blocks"])
            kv_block = kv_block or int(artifact.pool["block"])
        if paged and resolved is None:
            raise ValueError("paged KV cache requires a quantized state "
                             "(state_bits or an artifact state policy)")
        self.paged = paged
        self.share_prefix = share_prefix
        self.state = self.api.init_decode_state(cfg, max_slots, max_seq,
                                                state_dtype, state_bits=resolved,
                                                block=kv_block, paged=paged,
                                                pool_blocks=pool_blocks)
        if paged:
            blk = self.state[0].block
            if artifact is not None and artifact.pool is not None and (
                    blk != int(artifact.pool["block"])):
                # resolve_block silently shrank the block because it does not
                # divide max_seq — the pool would then cover fewer tokens at
                # different per-block bytes than the budget priced
                raise ValueError(
                    f"artifact pool block {artifact.pool['block']} does not "
                    f"divide max_seq={max_seq}; serve with a max_seq multiple "
                    f"of the searched block length")
            self.pool = kvcache.BlockPool(self.state[0].num_blocks - 1)
            self._kv_blk = blk
            self._host_tables = np.full((max_slots, max_seq // blk), -1, np.int32)
            self._shared_blocks: dict[int, set[int]] = {}
            self._reserved: dict[int, int] = {}
            self._tables_dirty = False
        else:
            self.pool = None
        #: state-entry name -> packed bits (the state analogue of packed_bits)
        self.state_bits = kvcache.packed_state_bits(self.state)
        if artifact is not None:
            # bidirectional: wrong-width caches fail, a searched state entry
            # the engine left fp fails, and a state policy searched on a
            # different KV surface (head geometry / entry set) fails too —
            # slots/max_seq may differ (geometry-independent surface hash)
            surface = (kvcache.state_layer_infos(cfg, max_slots, max_seq)
                       if artifact.state_policy is not None else None)
            kvcache.verify_state_bits(self.state, artifact, surface=surface)
        # autotuned fused decode-step configs (v5, DESIGN.md §15): validate
        # the artifact table against THIS deployment's cache geometry and
        # install it process-wide before any decode program traces, so
        # serving replays the searched layouts instead of re-timing them
        self._install_kernel_configs()
        # observability (DESIGN.md §16): the metrics registry is the source
        # of truth behind the legacy stats() dict; the process-wide tracer
        # adds step-phase + lifecycle spans when (and only when) enabled
        self.metrics = obs_metrics.MetricsRegistry()
        for name in _COUNTER_KEYS:
            self.metrics.counter(name)
        self.metrics.counter("wall_s")
        #: full loop-turn wall time — admission + prefill turns included,
        #: not just decode-dispatch bodies (health medians agree with the
        #: phase spans on totals)
        self.metrics.histogram("step_time_s")
        self.metrics.histogram("ttft_s")
        self.metrics.histogram("itl_s")
        self._tracer = obs_trace.get_tracer()
        self._shed_events: list[dict] = []
        #: uid -> perf_counter start of the request's current lifecycle
        #: segment (tracing only)
        self._lc_marks: dict[int, float] = {}
        # graceful degradation (DESIGN.md §14): the live burst K walks the
        # shed ladder under pool pressure; tier index 0 = full service
        self._shed_policy = shed
        self._spec_ladder = spec_ladder(self.speculate)
        self._shed_tier = 0
        self._k_live = self.speculate
        self._straggler = StragglerMonitor()
        self.lifecycles: dict[int, RequestLifecycle] = {}
        self._queue: list[Request] = []
        self._cancel_requested: set[int] = set()
        self._pending_token: dict[int, int] = {}
        #: quantized decode-state layers need the burst snapshot/replay
        #: commit protocol (spec.loop); fp layers rewind for free
        self._quant_state = any(
            isinstance(layer, (kvcache.QuantizedKVLayer, kvcache.PagedKVLayer))
            for layer in (self.state if isinstance(self.state, list) else []))
        self._spec_jits: dict[int, dict] = {}  # burst length K -> jitted fns
        self._qimpl = qimpl

        api, cfg_ = self.api, cfg

        def decode(params, state, tokens, pos, key, inject, temperature,
                   top_k, top_p):
            logits, state = api.decode_step(params, cfg_, state, tokens, pos, qimpl=qimpl)
            # numerical anomaly guard (DESIGN.md §14): detect non-finite
            # logits per slot INSIDE the dispatch — the host sees one (B,)
            # bool, never the (B, V) logits — and sample from a zeroed row
            # so a poisoned slot cannot derail the batch's sampling math.
            # ``inject`` is the chaos harness's per-slot NaN needle (zeros
            # in production; an array arg, so injection never retraces).
            last = logits[:, -1] + inject[:, None]
            bad = ~jnp.isfinite(last).all(axis=-1)
            last = jnp.where(bad[:, None], 0.0, last)
            if temperature > 0.0:  # static arg: greedy never touches the key
                key, sub = jax.random.split(key)
                toks = sample(last, sub, temperature=temperature, top_k=top_k,
                              top_p=top_p)
            else:
                toks = sample(last)
            return toks, state, key, bad

        def prefill(params, tokens, lengths):
            _, st = api.prefill(params, cfg_, tokens=tokens, lengths=lengths,
                                qimpl=qimpl)
            return st

        # donate the decode state: the KV caches / SSM states alias in place
        # instead of being copied every token.  temperature/top_k/top_p ride
        # as static args so mutating engine.temperature between runs retraces
        # instead of silently keeping the init-time value.
        self._decode = jax.jit(decode, donate_argnums=(1,), static_argnums=(6, 7, 8))
        self._prefill = jax.jit(prefill)
        # chunked prefill: one donated-scratch dispatch per chunk.  The
        # offset rides as a traced scalar so every chunk of a prompt reuses
        # ONE compilation per (scratch_len, chunk) shape pair.
        if api.prefill_chunk is not None:
            def chunk_step(params, scratch, tokens, offset):
                return api.prefill_chunk(params, cfg_, scratch, tokens,
                                         offset, qimpl=qimpl)
            self._chunk_step = jax.jit(chunk_step, donate_argnums=(1,))
        else:
            self._chunk_step = None

    # -- autotuned kernel configs (DESIGN.md §15) --------------------------
    def _install_kernel_configs(self) -> None:
        """Replay a v5 artifact's autotuned fused decode-step configs.

        Every recorded candidate is bitwise-equivalent, so a wrong table can
        only cost speed — but a table tuned for a different cache geometry
        means the artifact does not describe this deployment at all, which
        is refused the same way a bitwidth mismatch is (``ArtifactError``).
        Keys for bit pairs the deployed policy doesn't use are tolerated.
        """
        from repro.checkpoint.store import ArtifactError
        from repro.kernels import autotune

        entries = (self.artifact.kernel_configs
                   if self.artifact is not None else None)
        if not entries:
            return
        qlayers = [l for l in (self.state if isinstance(self.state, list) else [])
                   if isinstance(l, (kvcache.QuantizedKVLayer,
                                     kvcache.PagedKVLayer))]
        if not qlayers:
            raise ArtifactError(
                "policy artifact carries kernel_configs but the engine built "
                "a float decode state (no fused quantized decode step exists "
                "to configure)")
        lyr = qlayers[0]
        try:
            autotune.validate_configs(
                entries, heads=lyr.shape[2], head_dim=lyr.shape[3],
                block=lyr.block,
                bit_pairs={(l.k_bits, l.v_bits) for l in qlayers})
        except ValueError as e:
            raise ArtifactError(
                f"policy artifact kernel_configs do not fit this "
                f"deployment: {e}") from e
        autotune.set_active_configs(entries)
        # replayed configs land in the trace next to the live step times, so
        # a Perfetto timeline shows WHICH searched layout each step ran
        tr = obs_trace.get_tracer()
        if tr.enabled:
            for e in entries:
                tr.instant("kernel_config_replayed", cat="kernel",
                           track="kernel", args=dict(e))

    # -- observability (DESIGN.md §16) ------------------------------------
    def _count(self, name: str, n: int = 1) -> None:
        self.metrics.counter(name).inc(n)

    def _nsteps(self) -> int:
        return int(self.metrics.counter("decode_steps").value)

    def _span(self, name: str, **args):
        """A step-phase span: trace event + ``phase/<name>`` histogram when
        tracing is enabled, the shared no-op singleton otherwise."""
        tr = self._tracer
        if not tr.enabled:
            return obs_trace.NOOP_SPAN
        return tr.span(name, cat="phase", track="engine",
                       hist=self.metrics.histogram("phase/" + name),
                       args=args or None)

    def _observe_transition(self, lc: RequestLifecycle, old: RequestState,
                            new: RequestState, now: float,
                            diagnostic: str) -> None:
        """Lifecycle observer: close the span for the segment that just
        ended on the request's own trace track, mark terminal states with
        an instant.  Keyed off the SAME validated transitions the resource
        accounting uses (serve/lifecycle.py)."""
        tr = self._tracer
        if not tr.enabled:
            self._lc_marks.pop(lc.uid, None)
            return
        t = tr.now()
        track = f"req/{lc.uid}"
        t0 = self._lc_marks.pop(lc.uid, None)
        if t0 is not None:
            tr.complete(old.value, ts=t0, dur=t - t0, cat="request",
                        track=track, args={"uid": lc.uid})
        if new in (RequestState.DONE, RequestState.FAILED,
                   RequestState.CANCELLED, RequestState.TIMED_OUT):
            tr.instant(new.value, cat="request", track=track,
                       args={"uid": lc.uid,
                             "diagnostic": diagnostic or lc.diagnostic,
                             "preemptions": lc.preemptions})
        else:
            if new is RequestState.QUEUED:  # preemption / admission rollback
                tr.instant("requeued", cat="request", track=track,
                           args={"uid": lc.uid, "diagnostic": diagnostic})
            self._lc_marks[lc.uid] = t

    # -- fault injection (runtime/resilience.py) ---------------------------
    def _fault(self, site: str, step: int | None = None) -> bool:
        """Consume-once poll of the injector at a serve fault site."""
        if self._injector is None:
            return False
        if step is None:
            step = self._nsteps()
        return self._injector.fires(site, step)

    # -- speculative decode (DESIGN.md §13) -------------------------------
    def _spec_fn(self, k: int):
        """ONE jitted draft-K / verify / accept / commit step for burst K.

        Cached per K: the burst shrinks near ``max_seq`` (K_eff), so at most
        ``speculate`` distinct compilations exist.  The whole round is a
        single dispatch — no host decision exists between its stages, so the
        snapshot, the K draft steps (low-bit containers, appending into the
        shared cache), the restore, the batched K+1 verify pass, the
        accept/reject math, and the bitwise-exact commit replay (spec.loop)
        all fuse into one donated-state call; the only per-step host
        transfer is (acc, out_tokens).
        """
        if k in self._spec_jits:
            return self._spec_jits[k]
        api, cfg_, qimpl = self.api, self.cfg, self._qimpl
        quant = self._quant_state

        def spec_step(params, dparams, state, tokens, pos, key, inject_draft,
                      inject_verify, temperature, top_k, top_p):
            saved = spec_loop.snapshot_state(state, pos, k) if quant else None
            tok, d_toks, d_logits = tokens, [], []
            # per-slot draft anomaly flag (DESIGN.md §14): sticky across the
            # burst; a poisoned slot's draft logits zero out so its (garbage)
            # proposals stay finite, and forcing acc=0 below makes the round
            # degrade to the exact non-speculative verify token for that slot
            draft_bad = jnp.zeros((tokens.shape[0],), bool)
            for j in range(k):
                logits, state = api.decode_step(dparams, cfg_, state, tok,
                                                pos + j, qimpl=qimpl)
                last = logits[:, -1]
                if j == 0:
                    last = last + inject_draft[:, None]
                draft_bad = draft_bad | ~jnp.isfinite(last).all(axis=-1)
                last = jnp.where(draft_bad[:, None], 0.0, last)
                if temperature > 0.0:
                    key, sub = jax.random.split(key)
                    t = sample(last, sub, temperature=temperature, top_k=top_k,
                               top_p=top_p)
                else:
                    t = sample(last)
                d_toks.append(t)
                d_logits.append(last)
                tok = t[:, None]
            d_toks = jnp.stack(d_toks, axis=1)
            d_logits = jnp.stack(d_logits, axis=1)
            if quant:
                state = spec_loop.restore_state(state, saved, pos, k)
            burst = jnp.concatenate([tokens, d_toks], axis=1)   # (B, K+1)
            logits, state, burst_kv = api.decode_verify(params, cfg_, state,
                                                        burst, pos, qimpl=qimpl)
            logits = logits + inject_verify[:, None, None]
            verify_bad = ~jnp.isfinite(logits).all(axis=(1, 2))
            logits = jnp.where(verify_bad[:, None, None], 0.0, logits)
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                acc, out = spec_loop.accept_tokens(
                    logits, d_toks, d_logits, sub, temperature=temperature,
                    top_k=top_k, top_p=top_p)
            else:
                acc, out = spec_loop.accept_tokens(logits, d_toks, d_logits,
                                                   None)
            # poisoned slots accept nothing: with acc=0 the emitted token is
            # the verify pass's position-0 output — byte-for-byte the token
            # the non-speculative engine would have produced (draft fallback)
            acc = jnp.where(draft_bad | verify_bad, 0, acc)
            if quant:
                state = spec_loop.commit_state(state, saved, pos, acc,
                                               burst_kv, k, qimpl=qimpl)
            return acc, out, state, key, draft_bad, verify_bad

        fn = jax.jit(spec_step, donate_argnums=(2,), static_argnums=(8, 9, 10))
        self._spec_jits[k] = fn
        return fn

    def _burst_len(self, active: list[int]) -> int:
        """Burst K for this step: the LIVE K (configured K minus any shed
        tiers), shrunk so no slot's burst can write past ``max_seq - 1``
        (active slots sit at ``pos <= max_seq - 2``, so this is >= 1
        whenever speculation is live)."""
        max_pos = max(self.slots[i].pos for i in active)
        return max(min(self._k_live, self.max_seq - 1 - max_pos), 0)

    def _spec_step(self, active: list[int], tokens_h, pos_h, k: int,
                   inject_draft, inject_verify):
        """One draft-K / verify / accept / commit round -> (emitted tokens
        per active slot (1..K+1 each: accepted draft prefix + bonus),
        per-slot draft/verify non-finite flags)."""
        with self._span("dispatch", k=k):
            acc, out, self.state, self._key, draft_bad, verify_bad = \
                self._spec_fn(k)(
                    self.params, self.draft_params, self.state,
                    jnp.asarray(tokens_h), jnp.asarray(pos_h), self._key,
                    jnp.asarray(inject_draft), jnp.asarray(inject_verify),
                    self.temperature, self.top_k, self.top_p)
        with self._span("device_sync"):
            jax.block_until_ready((acc, out, draft_bad, verify_bad))
        acc_h = np.asarray(acc)      # the step's ONLY host transfer:
        out_h = np.asarray(out)      # (B,) accepts + (B, K+1) tokens + flags
        self._count("spec_steps")
        emitted: dict[int, list[int]] = {}
        for i in active:
            a = int(acc_h[i])
            emitted[i] = [int(t) for t in out_h[i, : a + 1]]
            self._count("spec_proposed", k)
            self._count("spec_accepted", a)
        return emitted, np.asarray(draft_bad), np.asarray(verify_bad)

    # -- state surgery ---------------------------------------------------
    def _insert_rows(self, slot_ids: list[int], st_new: Any,
                     lengths: jax.Array) -> None:
        """Tree-insert rows of a batched prefill state into their slots.

        fp leaves scatter directly (one scatter per leaf, no per-row
        full-cache copies); quantized KV layers quantize the fp prefill
        rows block-wise on the way in — kvcache.insert_state_rows is the
        shared walker (the calibration env admits the same way).
        """
        self.state = kvcache.insert_state_rows(self.state, jnp.asarray(slot_ids),
                                               st_new, lengths)

    # -- paged block bookkeeping (DESIGN.md §12) --------------------------
    def _push_tables(self) -> None:
        """Mirror the host block tables into every paged layer's device copy.

        Rows of slots still mid-chunked-prefill push as -1: their mapped
        blocks hold no bytes until the final insert, and the lockstep decode
        dispatch must keep appending those slots' (idle) writes into the
        trash block instead of corrupting mapped-but-unwritten blocks.  The
        real row pushes when the prefill completes (``_finish_prefill`` sets
        ``_tables_dirty``).
        """
        if not self._tables_dirty:
            return
        tbl = self._host_tables
        masked = [i for i, s in enumerate(self.slots) if s.prefilling]
        if masked:
            tbl = tbl.copy()
            tbl[masked] = -1
        # one device copy PER layer: the decode step donates the state, and
        # donation rejects the same buffer appearing in two arguments
        self.state = [kvcache.paged.with_table(layer, jnp.asarray(tbl))
                      for layer in self.state]
        self._tables_dirty = False

    def _map_slot_blocks(self, slot_id: int, req: Request) -> bool:
        """Map blocks covering positions ``[0, len(prompt) - 1]`` for a slot
        and RESERVE its decode growth (blocks the appends will cross into,
        plus one copy-on-write split if the write block is shared), so a
        mid-decode allocation can never fail for an admitted request.

        Blocks whose occupied rows are bit-identical to a block some other
        slot already maps (a shared prefix, block-aligned coverage) map the
        SAME physical block with a bumped refcount instead of allocating —
        the first append into such a block copies it first (copy-on-write,
        ``_ensure_append_blocks``).  Returns False (with full rollback) when
        the pool cannot cover prompt + growth, so the caller can requeue the
        request instead of half-admitting it.
        """
        blk = self._kv_blk
        prompt = req.prompt
        length = len(prompt)
        w_new = length - 1                      # head rows written at admission
        tb_first = (length - 1) // blk          # block the replay append hits
        # highest position this request can ever write: at least the replay
        # append at length-1 (even for max_new_tokens <= 0 the decode loop
        # runs one step), at most max_seq - 2 (run()'s stop condition) — plus
        # speculate burst headroom: a draft/verify burst transiently writes
        # up to K positions past the committed one (capped at max_seq - 1),
        # and reserving it here is what keeps a speculative step from ever
        # stranding an admitted request mid-decode (DESIGN.md §13)
        last_pos = min(max(length - 1, length - 2 + req.max_new_tokens),
                       self.max_seq - 2)
        last_pos = min(last_pos + self._k_live, self.max_seq - 1)
        tb_last = last_pos // blk
        donor, common = None, 0
        if self.share_prefix:
            for other, slot in enumerate(self.slots):
                # a prefilling slot cannot donate: its mapped blocks hold no
                # pool bytes until the final scratch insert lands
                if other == slot_id or slot.free or slot.prefilling:
                    continue
                lcp = 0
                for a, b in zip(prompt, slot.req.prompt):
                    if a != b:
                        break
                    lcp += 1
                if lcp > common:
                    donor, common = other, lcp
        plan: list[tuple[int, int | None]] = []  # (logical block, donor bid)
        n_fresh = 0
        for j in range(tb_first + 1):
            end_new = min(w_new, (j + 1) * blk)
            src = None
            if donor is not None and self._host_tables[donor, j] >= 0:
                w_d = self.slots[donor].pos
                # identical occupancy, fully inside the common prefix:
                # the donor's block bytes ARE this slot's block bytes
                if min(w_d, (j + 1) * blk) == end_new and end_new <= common:
                    src = int(self._host_tables[donor, j])
            plan.append((j, src))
            n_fresh += src is None
        # growth: every block past the first write block, plus the CoW copy
        # if the first write block itself is shared
        growth = (tb_last - tb_first) + (plan[tb_first][1] is not None)
        if self.pool.available < n_fresh + growth:
            return False
        row = self._host_tables[slot_id]
        shared: set[int] = set()
        for j, src in plan:
            if src is not None:
                row[j] = self.pool.incref(src)
                shared.add(j)
            else:
                row[j] = self.pool.alloc()
        self.pool.reserve(growth)
        self._reserved[slot_id] = growth
        self._shared_blocks[slot_id] = shared
        self._tables_dirty = True
        return True

    def _map_chunked_blocks(self, slot_id: int, req: Request) -> bool:
        """Reserve a chunked admission's ENTIRE block need upfront; map
        nothing yet.

        Chunked slots take no shared-prefix donors (their bytes land only at
        the final insert, so there is nothing to compare against), so the
        whole span — head blocks plus decode growth plus burst headroom,
        the same ``last_pos`` formula as ``_map_slot_blocks`` — is a plain
        reservation.  Each chunk then maps its fully-filled blocks via
        ``_grow_alloc`` (reservation -> mapped, one ledger), which keeps
        ``_reserved[slot] == _required_growth(slot, k)`` exact at every
        progress point with NO resync — ``check_invariants`` is unchanged.
        Returns False (nothing touched) when the pool cannot cover the span.
        """
        blk = self._kv_blk
        length = len(req.prompt)
        last_pos = min(max(length - 1, length - 2 + req.max_new_tokens),
                       self.max_seq - 2)
        last_pos = min(last_pos + self._k_live, self.max_seq - 1)
        total = last_pos // blk + 1
        if self.pool.available < total:
            return False
        self.pool.reserve(total)
        self._reserved[slot_id] = total
        self._shared_blocks[slot_id] = set()
        return True

    def _grow_alloc(self, slot_id: int) -> int:
        """Allocate one block against the slot's admission-time reservation."""
        n = self._reserved.get(slot_id, 0)
        if n > 0:
            self.pool.unreserve(1)
            self._reserved[slot_id] = n - 1
        return self.pool.alloc()

    def _ensure_append_blocks(self, active: list[int], span: int = 1) -> None:
        """Before a decode step: every block an active slot can write this
        step — positions ``[pos, pos + span - 1]``, span = K_eff + 1 under
        speculation — must be mapped (allocate on demand at block
        boundaries) and exclusively owned (copy-on-write when a shared
        prefix diverges)."""
        cow_src, cow_dst = [], []
        for i in active:
            pos = self.slots[i].pos
            last = min(pos + span - 1, self.max_seq - 1)
            for tb in range(pos // self._kv_blk, last // self._kv_blk + 1):
                bid = int(self._host_tables[i, tb])
                if bid < 0:
                    self._host_tables[i, tb] = self._grow_alloc(i)
                    self._tables_dirty = True
                elif self.pool.refcount(bid) > 1:
                    fresh = self._grow_alloc(i)
                    self.pool.cow_copies += 1
                    self.pool.decref(bid)
                    self._host_tables[i, tb] = fresh
                    cow_src.append(bid)
                    cow_dst.append(fresh)
                    self._tables_dirty = True
        if cow_src:
            self.state = [kvcache.paged.copy_blocks(layer, cow_src, cow_dst)
                          for layer in self.state]
        self._push_tables()

    def _free_slot_blocks(self, slot_id: int) -> None:
        for bid in self._host_tables[slot_id]:
            if bid >= 0:
                self.pool.decref(int(bid))
        self._host_tables[slot_id] = -1
        self.pool.unreserve(self._reserved.pop(slot_id, 0))
        self._shared_blocks.pop(slot_id, None)
        self._tables_dirty = True

    # -- graceful degradation (DESIGN.md §14) -----------------------------
    def _required_growth(self, slot_id: int, k: int) -> int:
        """Blocks an active slot still needs reserved to finish under burst
        headroom ``k``: unmapped logical blocks in its remaining write span,
        plus one copy-on-write split per still-shared mapped block there.
        Mirrors ``_map_slot_blocks``'s admission-time formula evaluated at
        the current write position — ``_reserved[slot] == this`` is the
        reservation-accounting invariant ``check_invariants`` pins."""
        slot = self.slots[slot_id]
        req, blk = slot.req, self._kv_blk
        length = len(req.prompt)
        last_pos = min(max(length - 1, length - 2 + req.max_new_tokens),
                       self.max_seq - 2)
        last_pos = min(last_pos + k, self.max_seq - 1)
        need = 0
        for tb in range(slot.pos // blk, last_pos // blk + 1):
            bid = int(self._host_tables[slot_id, tb])
            if bid < 0 or self.pool.refcount(bid) > 1:
                need += 1
        return need

    def _set_live_k(self, k: int) -> bool:
        """Change the live speculation burst length, resyncing every active
        slot's growth reservation to the new headroom.  Shrinking always
        succeeds (it releases reservations back to the pool — that is the
        shed ladder's whole point); growing back is refused (False) when the
        pool cannot re-secure the larger headroom for ALL active slots, so
        restoring speculation can never strand an admitted request."""
        if k == self._k_live:
            return True
        if self.paged:
            deltas: dict[int, int] = {}
            for i, s in enumerate(self.slots):
                if s.free:
                    continue
                deltas[i] = self._required_growth(i, k) - self._reserved.get(i, 0)
            grow = sum(d for d in deltas.values() if d > 0)
            shrink = -sum(d for d in deltas.values() if d < 0)
            if grow > self.pool.available + shrink:
                return False
            for i, d in sorted(deltas.items(), key=lambda kv: kv[1]):
                if d < 0:                  # releases first: frees headroom
                    self.pool.unreserve(-d)
                elif d > 0:
                    self.pool.reserve(d)
                self._reserved[i] = self._reserved.get(i, 0) + d
        self._k_live = k
        return True

    def _shed_event(self, action: str, **extra) -> None:
        ev = {"action": action, "step": self._nsteps(),
              "tier": self._shed_tier, "k": self._k_live, **extra}
        self._shed_events.append(ev)
        self._tracer.instant("shed:" + action, cat="degradation",
                             track="engine", args=ev)

    def _maybe_shed(self, waiting: list[Request]) -> bool:
        """ONE degradation action for this loop turn (True if state changed):
        walk the speculation ladder down a tier (releasing draft burst
        headroom reservations), then — ladder exhausted — preempt the
        lowest-priority resident strictly below the best waiting priority.
        Neither applies -> fall back to plain backpressure waiting."""
        pol = self._shed_policy
        if pol is None:
            return False
        if pol.spec_tiers and self._shed_tier < len(self._spec_ladder) - 1:
            if self._set_live_k(self._spec_ladder[self._shed_tier + 1]):
                self._shed_tier += 1
                self._shed_event("spec_shed")
                return True
        return self._preempt_for(waiting)

    def _preempt_for(self, waiting: list[Request]) -> bool:
        """Preempt the lowest-priority resident strictly below the best
        waiting priority (equal priorities never thrash).  Fires from the
        shed ladder under block-pool pressure AND directly under slot
        pressure (all slots busy, a higher-priority request waiting)."""
        pol = self._shed_policy
        if pol is None or not pol.preempt or not waiting:
            return False
        best = max(r.priority for r in waiting)
        victims = [i for i, s in enumerate(self.slots)
                   if not s.free and s.req.priority < best]
        if not victims:
            return False
        # lowest priority first; ties preempt the least-progressed slot
        # (least replayed work)
        victim = min(victims, key=lambda i: (
            self.slots[i].req.priority, len(self.slots[i].generated)))
        self._preempt(victim)
        return True

    def _relax_shed(self) -> None:
        """Pressure-free turn: climb back one ladder tier if the pool can
        re-secure the bigger burst headroom for every active slot."""
        pol = self._shed_policy
        if (pol is None or not pol.restore or self._shed_tier == 0):
            return
        if self._set_live_k(self._spec_ladder[self._shed_tier - 1]):
            self._shed_tier -= 1
            self._shed_event("restore")

    def _preempt(self, slot_id: int) -> None:
        """Snapshot a victim's progress and send it back to QUEUED: its
        prompt + generated tokens become the resumed request's prompt, which
        replays through the normal prefill/shared-prefix path; the remaining
        token budget shrinks by what was already produced, so the resumed
        stream picks up exactly where the victim stopped."""
        s = self.slots[slot_id]
        req = s.req
        lc = self.lifecycles.get(req.uid)
        now = time.monotonic()
        if lc is not None:
            lc.transition(RequestState.QUEUED, now,
                          diagnostic="preempted under pool pressure")
            lc.preemptions += 1
            lc.resume_tokens.extend(s.generated)
            lc.prefill_progress = 0  # a mid-chunk victim restarts its prefill
        self._count("preemptions")
        self._shed_event("preempt", uid=req.uid, at_tokens=len(s.generated))
        resumed = dataclasses.replace(
            req, prompt=req.prompt + s.generated,
            max_new_tokens=req.max_new_tokens - len(s.generated))
        self._release_slot(slot_id)
        self._queue.append(resumed)

    # -- lifecycle bookkeeping (serve/lifecycle.py) -----------------------
    def submit(self, req: Request, on_token=None) -> RequestLifecycle:
        """Enqueue a request (usable mid-``run`` from a step hook).  Creates
        the lifecycle record; admission order is priority-first, FIFO within
        a priority class.

        ``on_token(uid, token)`` — optional streaming callback, fired from
        the commit phase for every token the moment it commits (speculative
        burst tokens fire individually, in order).  Tokens also land in the
        ``poll()`` ring regardless of whether a callback is installed.
        """
        lc = RequestLifecycle(uid=req.uid, priority=req.priority,
                              deadline_s=req.deadline_s,
                              ttft_budget_s=req.ttft_budget_s,
                              enqueued_t=time.monotonic())
        existing = self.lifecycles.get(req.uid)
        if existing is not None and not existing.terminal:
            raise LifecycleError(
                f"request uid {req.uid} is already live ({existing.state.value})")
        lc.observer = self._observe_transition
        tr = self._tracer
        if tr.enabled:
            self._lc_marks[req.uid] = tr.now()
            tr.instant("submit", cat="request", track=f"req/{req.uid}",
                       args={"uid": req.uid, "priority": req.priority,
                             "prompt_tokens": len(req.prompt),
                             "max_new_tokens": req.max_new_tokens})
        self.lifecycles[req.uid] = lc
        if on_token is not None:
            self._on_token[req.uid] = on_token
        self._queue.append(req)
        return lc

    def poll(self):
        """Drain committed-but-unread tokens: yields ``(uid, token)`` in
        commit order.  Call between ``run()`` invocations or from a step
        hook mid-run; the ring keeps the most recent 65536 events."""
        while self._token_events:
            yield self._token_events.popleft()

    def cancel(self, uid: int) -> None:
        """Request cancellation; takes effect at the next loop turn (the
        request may still complete first — cancelling a terminal request is
        a no-op, never an error)."""
        self._cancel_requested.add(uid)

    def _release_slot(self, slot_id: int) -> None:
        """Free a slot's compute + paged resources (no lifecycle change)."""
        if self.paged:
            self._free_slot_blocks(slot_id)
        self.slots[slot_id] = _Slot()
        self._pending_token.pop(slot_id, None)
        self._scratch.pop(slot_id, None)
        self._chunk_head.pop(slot_id, None)

    def _finalize(self, slot_id: int | None, req: Request,
                  state: RequestState, results: dict[int, list[int]],
                  diagnostic: str = "") -> None:
        """Move a request to a terminal state and free its resources.

        The lifecycle transition is the free-exactly-once guard: a second
        finalization of the same request raises ``LifecycleError`` before
        any slot/block/reservation is touched twice.
        """
        lc = self.lifecycles.get(req.uid)
        gen = list(self.slots[slot_id].generated) if slot_id is not None else []
        if lc is not None:
            lc.transition(state, time.monotonic(), diagnostic)
            lc.tokens = lc.resume_tokens + gen
            results[req.uid] = lc.tokens
        else:
            results[req.uid] = gen
        if slot_id is not None:
            self._release_slot(slot_id)
        self._on_token.pop(req.uid, None)
        self._count({RequestState.DONE: "completed",
                     RequestState.FAILED: "failed",
                     RequestState.CANCELLED: "cancelled",
                     RequestState.TIMED_OUT: "timed_out"}[state])

    def _reap(self, now: float, results: dict[int, list[int]]) -> None:
        """Apply pending cancellations and deadline/TTFT expiries, queued
        and resident alike, before this turn's admission."""
        for uid in sorted(self._cancel_requested):
            lc = self.lifecycles.get(uid)
            if lc is None or lc.terminal:
                self._cancel_requested.discard(uid)
                continue
            qi = next((j for j, r in enumerate(self._queue) if r.uid == uid),
                      None)
            if qi is not None:
                self._finalize(None, self._queue.pop(qi),
                               RequestState.CANCELLED, results,
                               diagnostic="cancelled while queued")
            else:
                si = next((i for i, s in enumerate(self.slots)
                           if not s.free and s.req.uid == uid), None)
                if si is not None:
                    self._finalize(si, self.slots[si].req,
                                   RequestState.CANCELLED, results,
                                   diagnostic="cancelled mid-decode")
            self._cancel_requested.discard(uid)
        for j in range(len(self._queue) - 1, -1, -1):
            req = self._queue[j]
            lc = self.lifecycles.get(req.uid)
            why = lc.expired(now) if lc is not None else None
            if why is not None:
                self._finalize(None, self._queue.pop(j),
                               RequestState.TIMED_OUT, results,
                               diagnostic=f"{why} budget exceeded while queued")
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            lc = self.lifecycles.get(s.req.uid)
            why = lc.expired(now) if lc is not None else None
            if why is not None:
                self._finalize(i, s.req, RequestState.TIMED_OUT, results,
                               diagnostic=f"{why} budget exceeded mid-decode")

    def _row_tables(self, with_head: list[tuple[int, list[int]]],
                    pad: int) -> np.ndarray:
        """Physical write destinations per (prefill row, logical block).

        -1 skips the write: pad blocks past the row's head rows, and
        shared-prefix blocks whose bytes a donor slot already holds (or
        writes in this very batch — same rows, same quantizer, same bits).
        """
        blk = self._kv_blk
        npb = -(-pad // blk)
        out = np.full((len(with_head), npb), -1, np.int32)
        for r, (slot_id, head) in enumerate(with_head):
            shared = self._shared_blocks.get(slot_id, set())
            for j in range(min(npb, -(-len(head) // blk))):
                if j not in shared:
                    out[r, j] = self._host_tables[slot_id, j]
        return out

    def _insert_rows_paged(self, with_head, st_new, lengths, pad: int) -> None:
        row_tables = self._row_tables(with_head, pad)
        new_state = []
        for layer, new in zip(self.state, st_new):
            new_state.append(kvcache.paged.insert_prefill_rows(
                layer, row_tables, new["k"], new["v"], valid_len=lengths))
        self.state = new_state

    # -- admission ---------------------------------------------------------
    def _admit(self, assignments: list[tuple[int, Request]]) -> list[Request]:
        """Admit requests into free slots; one padded prefill for the batch.

        Returns the requests that could NOT be admitted (paged pool too full
        to cover their prompts) for the caller to requeue.
        """
        with_head: list[tuple[int, list[int]]] = []
        rejected: list[Request] = []
        admitted: list[Request] = []
        now = time.monotonic()
        for slot_id, req in assignments:
            prompt = req.prompt
            assert 1 <= len(prompt) < self.max_seq, (len(prompt), self.max_seq)
            lc = self.lifecycles.get(req.uid)
            if lc is not None:
                lc.transition(RequestState.PREFILL, now)
            slot = self.slots[slot_id]
            slot.req, slot.generated = req, []
            w = len(prompt) - 1
            if self._scheduler is not None and w >= 1:
                # chunked admission (DESIGN.md §17): the slot enters the
                # PREFILLING state with zero progress; the scheduler feeds
                # its head to the model chunk-by-chunk across loop turns,
                # and the request stays in lifecycle PREFILL until the final
                # chunk inserts.  No pending replay token yet — that is what
                # keeps the slot out of the decode dispatch.
                slot.pos = 0
                slot.prefilling = True
                if self.paged and not self._map_chunked_blocks(slot_id, req):
                    self.slots[slot_id] = _Slot()
                    if lc is not None:
                        lc.transition(RequestState.QUEUED, now)
                    rejected.append(req)
                    continue
                pad = min(_round_up(w, self.prefill_pad), self.max_seq)
                head = np.zeros((1, pad), np.int32)
                head[0, :w] = prompt[:-1]
                self._chunk_head[slot_id] = head
                if self.api.init_prefill_scratch is not None:
                    self._scratch[slot_id] = self.api.init_prefill_scratch(
                        self.cfg, pad)
                continue
            slot.pos = w
            if w == 0 and self.api.prefill_chunk is None:
                # length-1 prompts run no prefill; attention caches are
                # causal-masked so stale rows never leak, but SSM/hybrid
                # recurrent state is NOT position-masked — zero the slot's
                # rows so the request decodes from the initial state instead
                # of the previous occupant's leftovers
                self._reset_recurrent_rows(slot_id)
            if self.paged and not self._map_slot_blocks(slot_id, req):
                self.slots[slot_id] = _Slot()
                if lc is not None:   # pool too full: back to the queue
                    lc.transition(RequestState.QUEUED, now)
                rejected.append(req)
                continue
            admitted.append(req)
            self._pending_token[slot_id] = prompt[-1]  # replayed next step
            if len(prompt) > 1:
                with_head.append((slot_id, prompt[:-1]))
        if self.paged:
            self._push_tables()
        if with_head:
            pad = min(_round_up(max(len(h) for _, h in with_head),
                                self.prefill_pad), self.max_seq)
            toks = np.zeros((len(with_head), pad), np.int32)
            for row, (_, head) in enumerate(with_head):
                toks[row, : len(head)] = head
            lengths = jnp.asarray([len(h) for _, h in with_head], jnp.int32)
            st = self._prefill(self.params, jnp.asarray(toks), lengths)
            if self.paged:
                self._insert_rows_paged(with_head, st, lengths, pad)
            else:
                self._insert_rows([slot_id for slot_id, _ in with_head], st,
                                  lengths)
            self._count("prefill_tokens", sum(len(h) for _, h in with_head))
        now = time.monotonic()
        for req in admitted:
            lc = self.lifecycles.get(req.uid)
            if lc is not None:
                lc.transition(RequestState.DECODE, now)
        return rejected

    def _reset_recurrent_rows(self, slot_id: int) -> None:
        """Zero one slot's rows across every plain-array state leaf.

        Used for length-1 prompt admissions on recurrent families (see
        ``_admit``): quantized KV containers are skipped (attention is
        causal; their stale rows are already masked), every dense leaf with
        a leading slot axis zeroes its row.
        """
        def zero_row(leaf):
            if (isinstance(leaf, jax.Array) and leaf.ndim
                    and leaf.shape[0] == self.max_slots):
                return leaf.at[slot_id].set(jnp.zeros_like(leaf[slot_id]))
            return leaf
        self.state = jax.tree.map(
            zero_row, self.state,
            is_leaf=lambda x: isinstance(x, (kvcache.QuantizedKVLayer,
                                             kvcache.PagedKVLayer)))

    # -- chunked prefill (DESIGN.md §17) ----------------------------------
    def _run_chunks(self, n_decode: int) -> None:
        """Run this turn's budgeted prefill chunks (scheduler-planned).

        Decoder families carry fp K/V scratch across chunks (one donated
        dispatch per chunk, attention offset into the scratch); SSM/hybrid
        fall back to prefix recompute — the whole padded head re-prefills
        with ``lengths=[progress]`` each chunk and only the final (full-
        length) state is kept, trading quadratic total compute for the
        same bounded-stall interleaving.  Either way the live cache/state
        is only written at the final insert, with the SAME insert path and
        valid-length masking as an unchunked admission.
        """
        prefilling = [(i, len(s.req.prompt) - 1 - s.pos)
                      for i, s in enumerate(self.slots)
                      if not s.free and s.prefilling]
        plan = self._scheduler.plan(self._nsteps(), n_decode, prefilling)
        blk = self._kv_blk if self.paged else 0
        for slot_id, n in plan:
            s = self.slots[slot_id]
            req = s.req
            w = len(req.prompt) - 1
            p = s.pos
            with self._span("prefill_chunk", uid=req.uid, offset=p, n=n):
                if self._chunk_step is not None:
                    c = self.prefill_chunk
                    toks = np.zeros((1, c), np.int32)
                    toks[0, :n] = req.prompt[p:p + n]
                    self._scratch[slot_id] = self._chunk_step(
                        self.params, self._scratch[slot_id],
                        jnp.asarray(toks), jnp.asarray(p, jnp.int32))
                    st = self._scratch[slot_id]
                else:
                    # prefix recompute: lengths masks tokens past progress
                    # out of the recurrent-state update, so ONE compiled
                    # shape serves every chunk of this prompt
                    st = self._prefill(self.params,
                                       jnp.asarray(self._chunk_head[slot_id]),
                                       jnp.asarray([p + n], jnp.int32))
                jax.block_until_ready(st)
            s.pos = p + n
            self._count("prefill_tokens", n)
            self._count("prefill_chunks")
            lc = self.lifecycles.get(req.uid)
            if lc is not None:
                lc.prefill_progress = s.pos
            if self.paged:
                # map the blocks this chunk fully filled against the
                # admission-time reservation; the partial block stays
                # unmapped so the reservation ledger keeps matching
                # _required_growth exactly (and the zero-beyond-write probe
                # never reads a mapped-but-unwritten block)
                for tb in range(p // blk, s.pos // blk):
                    self._host_tables[slot_id, tb] = self._grow_alloc(slot_id)
            if s.pos >= w:
                self._finish_prefill(slot_id, st)

    def _finish_prefill(self, slot_id: int, st) -> None:
        """Final chunk landed: insert the carried state into the live cache
        and hand the slot to the decode dispatch (THIS turn — the caller
        recomputes the active set after the chunk phase, and the plan
        already charged this slot's first decode token)."""
        s = self.slots[slot_id]
        prompt = s.req.prompt
        w = len(prompt) - 1
        lengths = jnp.asarray([w], jnp.int32)
        if self.paged:
            blk = self._kv_blk
            for tb in range((w - 1) // blk + 1):
                if self._host_tables[slot_id, tb] < 0:
                    self._host_tables[slot_id, tb] = self._grow_alloc(slot_id)
            pad = self._chunk_head[slot_id].shape[1]
            self._insert_rows_paged([(slot_id, prompt[:-1])], st, lengths, pad)
            self._tables_dirty = True  # real row replaces the -1 mask
        else:
            self._insert_rows([slot_id], st, lengths)
        s.prefilling = False
        s.pos = w
        self._pending_token[slot_id] = prompt[-1]  # replayed next step
        self._scratch.pop(slot_id, None)
        self._chunk_head.pop(slot_id, None)
        lc = self.lifecycles.get(s.req.uid)
        if lc is not None:
            lc.transition(RequestState.DECODE, time.monotonic())

    # -- main loop -----------------------------------------------------------
    def run(self, requests: list[Request] = (), *,
            step_hook=None) -> dict[int, list[int]]:
        """Continuous-batching loop until every submitted request reaches a
        terminal lifecycle state.  Returns ``{uid: token stream}`` for every
        request that terminated during this call — partial streams for
        FAILED / CANCELLED / TIMED_OUT / never-admitted requests (consult
        ``engine.lifecycles[uid]`` for the terminal state and diagnostic).

        ``step_hook(engine, step)`` fires once per loop turn before
        admission; the chaos harness uses it for mid-run ``submit`` /
        ``cancel`` at deterministic steps.

        With the process-wide tracer enabled (``repro.obs.trace.enable()``)
        every turn additionally records a ``step`` span decomposed into the
        named phases of ``_turn`` plus per-request lifecycle spans — see
        ``trace_report()`` and DESIGN.md §16.  Tracing never changes the
        dispatch or sampling math, so traced runs are token-identical to
        untraced runs.
        """
        t0 = time.perf_counter()
        for req in requests:
            self.submit(req)
        results: dict[int, list[int]] = {}
        self._pending_token = {}
        tokens_h = np.zeros((self.max_slots, 1), np.int32)
        pos_h = np.zeros((self.max_slots,), np.int32)
        step_hist = self.metrics.histogram("step_time_s")

        while self._queue or self._active():
            tr = self._tracer
            step_idx = self._nsteps()
            step_span = (tr.span("step", cat="step", track="engine",
                                 hist=self.metrics.histogram("traced_step_s"),
                                 args={"step": step_idx})
                         if tr.enabled else obs_trace.NOOP_SPAN)
            with step_span:
                self._count("loop_turns")
                # the turn timer covers the WHOLE turn — admission and
                # prefill work included, not just the decode dispatch — so
                # health medians and the phase spans agree on totals
                with StepTimer() as turn:
                    dispatch_dt = self._turn(results, tokens_h, pos_h,
                                             step_hook)
                step_hist.observe(turn.dt)
                with self._span("bookkeeping"):
                    if dispatch_dt is not None:
                        self._after_dispatch(step_idx, dispatch_dt)
                    if tr.enabled:
                        tr.counter("queue_depth", len(self._queue))
                        tr.counter("active_slots",
                                   sum(not s.free for s in self.slots))
                        if self.paged:
                            tr.counter("pool_available", self.pool.available)
        self.metrics.counter("wall_s").inc(time.perf_counter() - t0)
        return results

    def _active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def _decode_active(self) -> list[int]:
        """Slots the decode dispatch steps this turn: active and NOT still
        mid-chunked-prefill (a prefill finishing this turn decodes from the
        NEXT turn, so the scheduler's per-turn token accounting is exact)."""
        return [i for i, s in enumerate(self.slots)
                if not s.free and not s.prefilling]

    def _turn(self, results: dict[int, list[int]], tokens_h, pos_h,
              step_hook) -> float | None:
        """One serve-loop turn, decomposed into the named step phases
        (DESIGN.md §16): hook -> reap -> admission -> prep -> dispatch ->
        device_sync -> commit.  Returns the dispatch+sync+transfer duration
        (the StragglerMonitor's latency signal), or None if no decode ran."""
        if step_hook is not None:
            with self._span("hook"):
                step_hook(self, self._nsteps())
        # cancellations + deadline/TTFT expiry, queued and resident alike
        with self._span("reap"):
            self._reap(time.monotonic(), results)
        with self._span("admission"):
            # fill free slots: one batched admission per loop turn, highest
            # priority first (stable sort: FIFO within a priority class)
            free = [i for i, s in enumerate(self.slots) if s.free]
            pressure = False
            if free and self._queue:
                self._queue.sort(key=lambda r: -r.priority)
                if self._fault("pool_exhaustion"):
                    # injected pool pressure: refuse the whole admission turn
                    # so the shed ladder reacts exactly as it would to a
                    # genuinely full pool
                    pressure = True
                else:
                    assignments = [(i, self._queue.pop(0))
                                   for i in free[: len(self._queue)]]
                    if self.batch_admission:
                        rejected = self._admit(assignments)
                    else:  # reference path: one padded prefill per request
                        rejected = []
                        for pair in assignments:
                            rejected += self._admit([pair])
                    # paged backpressure: requests the pool could not cover
                    # wait (shedding below) for completions to free blocks
                    self._queue[:0] = rejected
                    pressure = bool(rejected)
                    if rejected and not self._active():
                        # an idle pool that still rejects can never admit:
                        # shedding has nothing left to reclaim
                        raise RuntimeError(
                            f"request needs more KV blocks than the whole pool "
                            f"holds ({self.pool.num_blocks}); raise pool_blocks "
                            f"or the state_bytes budget")
            if pressure:
                # tiered degradation instead of indefinite backpressure:
                # shrink speculation headroom, then priority-gated preemption
                self._maybe_shed(self._queue)
            elif self._queue:
                # slot pressure (every slot busy, nothing rejected): a
                # strictly-higher-priority waiter may still preempt
                self._preempt_for(self._queue)
            else:
                self._relax_shed()
        act = self._decode_active()
        if self._scheduler is not None:
            # budgeted prefill chunks interleave with this turn's decode:
            # decode slots are charged first (they never wait on prefill),
            # chunks fill the remaining per-step token budget.  A slot whose
            # FINAL chunk lands joins this very turn's dispatch (its +1
            # decode charge is part of the chunk's planned cost): the insert
            # and the slot's entry into the lockstep step are atomic, so no
            # idle-slot write can ever land on freshly inserted rows.
            self._run_chunks(len(act))
            act = self._decode_active()
        if not act:
            if self._debug_invariants:
                self.check_invariants()  # pure-prefill turns sweep too
            return None
        if self.paged and self._fault("append_failure"):
            # the slot's paged append bookkeeping died: quarantine that
            # request alone; everyone else decodes this turn as usual
            victim = act[0]
            self._finalize(victim, self.slots[victim].req,
                           RequestState.FAILED, results,
                           diagnostic="paged append bookkeeping failure "
                                      "(injected fault)")
            act = self._decode_active()
            if not act:
                return None
        k_eff = self._burst_len(act) if self._k_live else 0
        with self._span("prep"):
            if self.paged:
                # map/CoW every block an active slot can write this step
                # (the whole K_eff+1 burst span under speculation)
                self._ensure_append_blocks(act, span=k_eff + 1)
            # one lock-step decode over all slots (idle slots step
            # harmlessly; paged idle slots append into the reserved trash
            # block)
            for i in act:
                s = self.slots[i]
                tokens_h[i, 0] = self._pending_token.get(
                    i, s.generated[-1] if s.generated else 0)
                pos_h[i] = s.pos
            # per-slot NaN needles (zeros in production: array args, so the
            # chaos harness injects without retracing the dispatch)
            inject = np.zeros((self.max_slots,), np.float32)
            if self._fault("nan_logit"):
                inject[act[0]] = np.float32("nan")
        step = self._nsteps()
        with StepTimer() as timer:
            if k_eff > 0:
                inj_draft = np.zeros((self.max_slots,), np.float32)
                if self._fault("nan_logit_draft"):
                    inj_draft[act[0]] = np.float32("nan")
                emitted, draft_bad, verify_bad = self._spec_step(
                    act, tokens_h, pos_h, k_eff, inj_draft, inject)
            else:
                with self._span("dispatch"):
                    toks_dev, self.state, self._key, bad_dev = self._decode(
                        self.params, self.state, jnp.asarray(tokens_h),
                        jnp.asarray(pos_h), self._key, jnp.asarray(inject),
                        self.temperature, self.top_k, self.top_p)
                with self._span("device_sync"):
                    jax.block_until_ready((toks_dev, bad_dev))
                toks = np.asarray(toks_dev)  # ONE (B,) int32 host transfer
                verify_bad = np.asarray(bad_dev)
                draft_bad = None
                emitted = {i: [int(toks[i])] for i in act}
        self._count("decode_steps")
        with self._span("commit"):
            self._commit(act, emitted, draft_bad, verify_bad, step, results)
        return timer.dt

    def _commit(self, act: list[int], emitted, draft_bad, verify_bad,
                step: int, results: dict[int, list[int]]) -> None:
        """Apply one dispatch round's tokens: quarantine poisoned slots,
        append accepted tokens (recording TTFT / inter-token gaps), finalize
        completed requests."""
        now = time.monotonic()
        tr = self._tracer
        ttft_hist = self.metrics.histogram("ttft_s")
        itl_hist = self.metrics.histogram("itl_s")
        for i in act:
            s = self.slots[i]
            self._pending_token.pop(i, None)
            if verify_bad[i]:
                # numerical quarantine: ONLY the poisoned request fails
                # (sampling already saw zeroed logits, so neighbours'
                # streams are untouched)
                self._count("nan_quarantined")
                if tr.enabled:
                    tr.instant("nan_quarantine", cat="anomaly",
                               track=f"req/{s.req.uid}",
                               args={"uid": s.req.uid, "step": step})
                self._finalize(i, s.req, RequestState.FAILED, results,
                               diagnostic=f"non-finite logits at decode "
                                          f"step {step}")
                continue
            if draft_bad is not None and draft_bad[i]:
                # poisoned draft, healthy verify: this round already fell
                # back to the non-speculative token for this slot
                self._count("nan_draft_fallbacks")
            lc = self.lifecycles.get(s.req.uid)
            first_of_turn = True
            for tok in emitted[i]:
                if lc is not None and lc.first_token_t is None:
                    lc.first_token_t = now
                    ttft_hist.observe(now - lc.enqueued_t)
                    if tr.enabled:
                        tr.instant("first_token", cat="request",
                                   track=f"req/{lc.uid}",
                                   args={"uid": lc.uid,
                                         "ttft_s": now - lc.enqueued_t})
                if s.last_token_t is not None:
                    # tokens of one speculative burst land together: only
                    # the first gap of the turn is a real inter-token wait
                    itl_hist.observe((now - s.last_token_t)
                                     if first_of_turn else 0.0)
                s.last_token_t = now
                first_of_turn = False
                s.generated.append(tok)
                s.pos += 1
                # streaming front-end: the commit IS the observable event
                # (TTFT above is the first COMMITTED token, not a prefill
                # chunk landing)
                self._token_events.append((s.req.uid, tok))
                cb = self._on_token.get(s.req.uid)
                if cb is not None:
                    cb(s.req.uid, tok)
                done = (tok == s.req.eos_id
                        or len(s.generated) >= s.req.max_new_tokens
                        or s.pos >= self.max_seq - 1)
                if done:
                    # a burst stops at its first terminal token: the rest
                    # of the accepted prefix is DROPPED, the slot (and
                    # its paged blocks) frees this very step
                    self._finalize(i, s.req, RequestState.DONE, results)
                    break

    def _after_dispatch(self, step: int, dt: float) -> None:
        """Post-dispatch bookkeeping: straggler latency signal -> shed one
        speculation tier (floor K=1: only real pool pressure turns
        speculation fully off), then the chaos harness's invariant sweep."""
        if (self._straggler.observe(step, dt)
                and self._shed_policy is not None
                and self._shed_policy.straggler_sheds_spec
                and self._k_live > 1
                and self._set_live_k(self._spec_ladder[self._shed_tier + 1])):
            self._shed_tier += 1
            self._shed_event("straggler_shed", dt=dt)
        if self._debug_invariants:
            self.check_invariants()

    # -- debug invariants (DESIGN.md §14) ---------------------------------
    def check_invariants(self) -> None:
        """Re-derive the engine's resource-accounting invariants from
        scratch and raise ``AssertionError`` on the first violation.  Runs
        after every loop turn under ``debug_invariants=True`` (the chaos
        harness) — O(slots x blocks) host work plus, for the zero-beyond-
        write probe, one device readback per active slot's write block.

        * refcount conservation: every usable block's pool refcount equals
          the number of host-table rows mapping it; allocated + free
          partitions the pool exactly (no leak, no double-free).
        * reservation accounting: the pool's reserved total is the sum of
          the per-slot ledgers, and each active slot's ledger equals its
          remaining growth requirement at the live burst K (an admitted
          request can always finish).
        * zero-beyond-write: in the block holding an active slot's last
          committed token, every position past the write offset holds zero
          levels — a freed block's previous occupant can never leak into a
          later request (kvcache/paged.py's contract).
        """
        if not self.paged:
            return
        pool = self.pool
        refs = np.zeros(pool.num_blocks + 1, np.int64)
        for i in range(self.max_slots):
            for bid in self._host_tables[i]:
                if bid >= 0:
                    refs[int(bid)] += 1
        for bid in range(1, pool.num_blocks + 1):
            if pool.refcount(bid) != refs[bid]:
                raise AssertionError(
                    f"block {bid}: pool refcount {pool.refcount(bid)} != "
                    f"{refs[bid]} host-table mappings (leak or double-free)")
        mapped = int((refs[1:] > 0).sum())
        if pool.allocated != mapped:
            raise AssertionError(
                f"pool accounts {pool.allocated} allocated blocks but the "
                f"tables map {mapped}")
        if pool.allocated + pool.free_count != pool.num_blocks:
            raise AssertionError(
                f"allocated {pool.allocated} + free {pool.free_count} != "
                f"pool size {pool.num_blocks}")
        ledger = sum(self._reserved.values())
        if pool.reserved != ledger:
            raise AssertionError(
                f"pool reserves {pool.reserved} blocks but per-slot ledgers "
                f"sum to {ledger}")
        blk = self._kv_blk
        for i, s in enumerate(self.slots):
            if s.free:
                if self._reserved.get(i, 0):
                    raise AssertionError(
                        f"free slot {i} still holds a growth reservation "
                        f"({self._reserved[i]} blocks)")
                continue
            need = self._required_growth(i, self._k_live)
            if self._reserved.get(i, 0) != need:
                raise AssertionError(
                    f"slot {i} (uid {s.req.uid}): reserved "
                    f"{self._reserved.get(i, 0)} blocks but needs {need} to "
                    f"finish at K={self._k_live}")
            off = s.pos % blk
            if s.pos == 0 or off == 0:
                continue  # last write filled its block exactly
            bid = int(self._host_tables[i, (s.pos - 1) // blk])
            if bid < 0 or self.pool.refcount(bid) > 1:
                continue  # shared blocks are a donor's bytes, not this slot's
            layer = next((l for l in self.state
                          if isinstance(l, kvcache.PagedKVLayer)), None)
            if layer is None:
                continue
            # one layer's device readback is probe enough per turn
            for side in (layer.k_packed, layer.v_packed):
                tail = np.asarray(side[bid, :, off:, :])
                if tail.any():
                    raise AssertionError(
                        f"slot {i} block {bid}: non-zero levels beyond "
                        f"write offset {off} (stale bytes would leak "
                        f"across free/realloc)")

    # -- stats -------------------------------------------------------------
    def stats(self) -> dict:
        """Counters plus a ``health`` section (latency + degradation state).

        This is a VIEW over ``self.metrics`` (the registry is the source of
        truth — see DESIGN.md §16), shaped exactly like the legacy ad-hoc
        stats dict so existing callers keep working.

        ``step_time_median_s`` is the median FULL loop turn (admission and
        prefill turns included, agreeing with ``wall_s`` and the traced
        phase spans); ``straggler_flagged`` still reflects the
        StragglerMonitor's dispatch-only latency signal; ``shed_tier`` /
        ``speculate_live_k`` show where on the degradation ladder the engine
        currently sits (0 / configured K = full service).
        """
        out = {k: int(self.metrics.counter(k).value) for k in _COUNTER_KEYS}
        out["wall_s"] = self.metrics.counter("wall_s").value
        out["shed_events"] = [dict(e) for e in self._shed_events]
        step_hist = self.metrics.histogram("step_time_s")
        out["health"] = {
            "step_time_median_s": (step_hist.percentile(50)
                                   if step_hist.count else 0.0),
            "dispatch_time_median_s": self._straggler.median(),
            "straggler_flagged": len(self._straggler.flagged),
            "shed_tier": self._shed_tier,
            "speculate_live_k": self._k_live,
            "queue_depth": len(self._queue),
            "active_slots": sum(not s.free for s in self.slots),
            "prefilling_slots": sum(s.prefilling for s in self.slots),
            "pool_available": self.pool.available if self.paged else None,
        }
        if self._scheduler is not None:
            recs = self._scheduler.records
            out["scheduler"] = {
                "prefill_chunk": self.prefill_chunk,
                "step_token_budget": self._scheduler.cfg.step_token_budget,
                "planned_turns": len(recs),
                "chunk_tokens": sum(r.chunk_tokens for r in recs),
                "max_step_tokens": max(
                    (r.decode_tokens + r.chunk_tokens + r.finish_tokens
                     for r in recs), default=0),
            }
        for name in ("ttft_s", "itl_s"):
            hist = self.metrics.histogram(name)
            if hist.count:
                out.setdefault("latency", {})[name] = hist.summary()
        if self.artifact is not None and self.artifact.report:
            cal = obs_calibration.calibration_ratios(self.artifact.report,
                                                     self.measured_costs())
            if cal:
                out["calibration"] = cal
        return out

    def trace_report(self) -> dict:
        """Decompose traced decode-step wall time into the named phases.

        Uses the ``phase/*`` histograms populated while the process-wide
        tracer is enabled (each phase span feeds its histogram on exit) and
        the ``traced_step_s`` parent-span histogram as the denominator.
        ``attributed_fraction`` is the share of total step wall time covered
        by named phases — the acceptance bar is >= 0.90 (the remainder is
        loop glue between spans).
        """
        total_hist = self.metrics.histogram("traced_step_s")
        total = total_hist.sum
        phases = {}
        attributed = 0.0
        for name in _PHASE_NAMES:
            h = self.metrics.get("phase/" + name)
            if h is None or h.count == 0:
                continue
            phases[name] = {
                "total_s": h.sum,
                "count": h.count,
                "mean_us": h.mean * 1e6,
                "p99_us": h.percentile(99) * 1e6,
                "fraction_of_step": (h.sum / total) if total else 0.0,
            }
            attributed += h.sum
        report = {
            "steps": total_hist.count,
            "total_s": total,
            "phases": dict(sorted(phases.items(),
                                  key=lambda kv: -kv[1]["total_s"])),
            "attributed_s": attributed,
            "attributed_fraction": (attributed / total) if total else 0.0,
            "unattributed_fraction": (1.0 - attributed / total) if total else 0.0,
        }
        if total_hist.count == 0:
            report["note"] = ("no traced steps recorded — enable the tracer "
                              "(repro.obs.trace.enable()) before run()")
        return report

    def weight_container_bytes(self) -> int:
        """HBM bytes the packed weights occupy (quantized leaves only)."""
        return sum(leaf.container_bytes() for leaf in jax.tree.leaves(
            self.params, is_leaf=lambda x: hasattr(x, "container_bytes"))
            if hasattr(leaf, "container_bytes"))

    def measured_costs(self) -> dict:
        """Deployment-side measurements of the artifact's predicted metrics.

        The cost-model calibration input (DESIGN.md §18): ``container_bytes``
        from the packed param tree, ``state_bytes`` from the cache
        accountants (only when the state is actually quantized — an fp cache
        measures a different thing than the search priced), ``latency_s``
        as the mean traced compute time per decode step (dispatch +
        device_sync — the part a roofline predicts; loop glue excluded)
        when traced steps exist.
        """
        out = {"container_bytes": float(self.weight_container_bytes())}
        if self._quant_state:
            out["state_bytes"] = float(self.state_container_bytes())
        disp = self.metrics.get("phase/dispatch")
        sync = self.metrics.get("phase/device_sync")
        if disp is not None and disp.count:
            lat = disp.mean + (sync.mean if sync is not None and sync.count
                               else 0.0)
            out["latency_s"] = float(lat)
        return out

    # -- state accounting ----------------------------------------------------
    def state_container_bytes(self) -> int:
        """HBM bytes the decode state occupies (dense containers / whole pool)."""
        total = 0
        for leaf in jax.tree.leaves(
                self.state,
                is_leaf=lambda x: hasattr(x, "container_bytes")):
            if hasattr(leaf, "container_bytes"):
                total += leaf.container_bytes()
            else:
                total += leaf.size * leaf.dtype.itemsize
        return total

    def allocated_state_bytes(self, *, peak: bool = True) -> int:
        """Paged: bytes of live (peak by default) blocks — what the
        ``state_bytes`` budget prices.  Dense: the full container (every
        slot pre-pays ``max_seq``, which is the point of going paged)."""
        if not self.paged:
            return self.state_container_bytes()
        n = self.pool.peak_allocated if peak else self.pool.allocated
        return sum(layer.allocated_bytes(n) for layer in self.state)

    # -- convenience ---------------------------------------------------------
    def generate(self, prompts: list[list[int]], max_new_tokens: int = 16) -> list[list[int]]:
        reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new_tokens)
                for i, p in enumerate(prompts)]
        out = self.run(reqs)
        return [out[i] for i in range(len(prompts))]
