"""Request lifecycle + graceful-degradation policy for the serve path (DESIGN.md §14).

The engine used to know exactly two request fates: "still running" and
"returned tokens".  Production traffic needs the full lattice::

    QUEUED -> PREFILL -> DECODE -> DONE
       |         |          |---> FAILED      (non-finite logits, append fault)
       |         |          |---> CANCELLED   (explicit engine.cancel)
       |         |          |---> TIMED_OUT   (deadline / TTFT budget)
       |         |          '---> QUEUED      (preempted under pool pressure)
       |         '--> QUEUED                  (admission rejected: pool full,
       |                                       or preempted mid-chunk)
       '--> CANCELLED | TIMED_OUT             (never admitted)

Under chunked prefill (DESIGN.md §17) PREFILL is not one atomic turn: the
request stays in PREFILL across every budgeted chunk, ``prefill_progress``
counting the head tokens landed so far, and every PREFILL edge above is
valid *between chunks* — cancel/deadline/preemption mid-chunk free the
partial scratch, blocks and reservations through the same exactly-once
finalization as any resident request.

``RequestLifecycle`` is the per-request record: every transition is
validated against the edges above and timestamped, terminal states are
absorbing (a second finalization raises ``LifecycleError`` — the
"free exactly once" contract the engine's slot/block/reservation
accounting rides on), and preemption snapshots the generated prefix in
``resume_tokens`` so the re-queued request replays it through the normal
prefill/shared-prefix machinery.

``ShedPolicy`` configures the tiered degradation ladder the engine walks
under pool pressure instead of waiting indefinitely:

  tier 0          full service (configured speculation K)
  tier 1..n-1     speculation shed K -> K//2 -> ... -> off; each step
                  releases the draft bursts' per-slot block-headroom
                  reservations back to the pool
  preemption      the lowest-priority resident request (strictly below the
                  best waiting request's priority — equal priorities never
                  thrash) is preempted: progress snapshotted, resources
                  freed, request re-enters QUEUED

When pressure clears the engine climbs back down one tier per pressure-free
turn, re-securing the speculation headroom reservations before re-raising K
(never strand an admitted request).
"""
from __future__ import annotations

import dataclasses
import enum


class LifecycleError(RuntimeError):
    """An illegal lifecycle transition (incl. double-finalization)."""


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"


TERMINAL_STATES = frozenset({RequestState.DONE, RequestState.FAILED,
                             RequestState.CANCELLED, RequestState.TIMED_OUT})

_LEGAL: dict[RequestState, frozenset[RequestState]] = {
    RequestState.QUEUED: frozenset({RequestState.PREFILL, RequestState.CANCELLED,
                                    RequestState.TIMED_OUT}),
    RequestState.PREFILL: frozenset({RequestState.DECODE, RequestState.QUEUED,
                                     RequestState.FAILED, RequestState.CANCELLED,
                                     RequestState.TIMED_OUT}),
    RequestState.DECODE: frozenset({RequestState.DONE, RequestState.FAILED,
                                    RequestState.CANCELLED, RequestState.TIMED_OUT,
                                    RequestState.QUEUED}),
}


@dataclasses.dataclass
class RequestLifecycle:
    """Per-request lifecycle record (timestamps are ``time.monotonic``)."""

    uid: int
    priority: int = 0
    deadline_s: float | None = None       # end-to-end budget from enqueue
    ttft_budget_s: float | None = None    # first-token budget from enqueue
    state: RequestState = RequestState.QUEUED
    enqueued_t: float = 0.0
    admitted_t: float | None = None
    first_token_t: float | None = None
    finished_t: float | None = None
    preemptions: int = 0
    #: chunked prefill (DESIGN.md §17): head tokens prefilled so far — the
    #: PREFILLING(progress) notion; stays 0 for whole-prompt admissions and
    #: resets with the request if a preemption sends it back to QUEUED
    prefill_progress: int = 0
    #: tokens generated before the most recent preemption; the resumed
    #: request replays them as prompt suffix, and the final stream is
    #: ``resume_tokens + generated``
    resume_tokens: list[int] = dataclasses.field(default_factory=list)
    #: final token stream (set at finalization, partial for non-DONE ends)
    tokens: list[int] = dataclasses.field(default_factory=list)
    diagnostic: str = ""
    history: list[tuple[str, float]] = dataclasses.field(default_factory=list)
    #: optional ``fn(lifecycle, old_state, new_state, now, diagnostic)``
    #: called after every validated transition — the serve engine hangs its
    #: tracing off this hook so per-request span timelines key off the SAME
    #: transitions the resource accounting does (DESIGN.md §16)
    observer: object = dataclasses.field(default=None, repr=False,
                                         compare=False)

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def transition(self, new: RequestState, now: float,
                   diagnostic: str = "") -> None:
        if self.terminal:
            raise LifecycleError(
                f"request {self.uid} already finalized as {self.state.value}; "
                f"refusing second transition to {new.value}")
        if new not in _LEGAL[self.state]:
            raise LifecycleError(
                f"request {self.uid}: illegal transition "
                f"{self.state.value} -> {new.value}")
        old = self.state
        self.state = new
        self.history.append((new.value, now))
        if diagnostic:
            self.diagnostic = diagnostic
        if new is RequestState.PREFILL:
            self.admitted_t = now
        elif new in TERMINAL_STATES:
            self.finished_t = now
        if self.observer is not None:
            self.observer(self, old, new, now, diagnostic)

    def expired(self, now: float) -> str | None:
        """Which budget (if any) this request has blown at ``now``."""
        if self.terminal:
            return None
        waited = now - self.enqueued_t
        if self.deadline_s is not None and waited > self.deadline_s:
            return "deadline"
        if (self.ttft_budget_s is not None and self.first_token_t is None
                and waited > self.ttft_budget_s):
            return "ttft"
        return None

    def ttft(self) -> float | None:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.enqueued_t

    def ttlt(self) -> float | None:
        """Time to last token (end-to-end latency from enqueue)."""
        if self.finished_t is None:
            return None
        return self.finished_t - self.enqueued_t


@dataclasses.dataclass(frozen=True)
class ShedPolicy:
    """Tiered graceful-degradation config (see module docstring).

    ``straggler_sheds_spec`` lets a flagged slow step shed one speculation
    tier (never below K=1 on the latency signal alone — only real pool
    pressure turns speculation fully off), giving degradation decisions the
    latency signal the ``StragglerMonitor`` produces.
    """

    spec_tiers: bool = True        # shed K -> K//2 -> ... -> 0 under pressure
    preempt: bool = True           # priority-gated preemption as the last tier
    straggler_sheds_spec: bool = True
    restore: bool = True           # climb back down when pressure clears


def spec_ladder(k: int) -> list[int]:
    """Degradation ladder for a configured burst K: [K, K//2, ..., 1, 0]."""
    out = []
    while k > 0:
        out.append(k)
        k //= 2
    out.append(0)
    return out
