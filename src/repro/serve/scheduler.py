"""Chunked-prefill scheduling under a per-step token budget (DESIGN.md §17).

The engine's legacy admission prefills every queued prompt in one padded
``(n_free, pad)`` call, so one long prompt stalls every decoding slot for a
full quadratic-attention prefill.  With ``prefill_chunk=C`` the engine
instead admits long prompts in the PREFILLING state and asks this scheduler,
once per loop turn, which prefilling slots may run one ``<= C``-token chunk
this turn.  The contract:

  * **Budget**: ``decode_tokens + chunk_tokens + finish_tokens <=
    step_token_budget`` every turn.  Decode slots are charged one token
    each (a speculative burst is one weight pass — the budget meters
    dispatch work, not emitted tokens); a chunk is charged its real token
    count ``n = min(C, remaining)``; a chunk that COMPLETES its prompt is
    charged one extra token (``finish_tokens``) because the engine runs the
    finished slot's first decode the same turn — the insert and the slot's
    entry into the lockstep dispatch must be atomic, or an idle-slot write
    could requantize real cache rows in between.
  * **Decode never starves**: the scheduler only ever allocates the budget
    LEFT OVER after every active decode slot is charged — decode runs every
    turn regardless of prefill backlog (starvation bound: 0 turns).
  * **Prefill never starves**: construction requires
    ``step_token_budget >= max_slots + prefill_chunk``, so even a full
    decode house leaves room for one full chunk; round-robin rotation
    guarantees every prefilling slot chunks at least once per
    ``len(prefilling)`` turns.
  * Chunks are all-or-nothing: a slot chunks only if its whole next chunk
    fits the remaining quota, so every non-final chunk is exactly ``C``
    tokens (one compiled chunk shape per scratch geometry).

Every ``plan()`` call appends a :class:`SchedRecord`, which is the
accounting surface ``tests/test_scheduler.py`` checks the invariants on.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Static chunked-prefill knobs (validated against the slot count)."""

    prefill_chunk: int
    step_token_budget: int

    def validate(self, max_slots: int) -> None:
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{self.prefill_chunk}")
        floor = max_slots + self.prefill_chunk
        if self.step_token_budget < floor:
            raise ValueError(
                f"step_token_budget={self.step_token_budget} cannot fit a "
                f"full decode house plus one chunk (need >= max_slots + "
                f"prefill_chunk = {max_slots} + {self.prefill_chunk} = "
                f"{floor}); a long prompt could starve forever")


@dataclasses.dataclass(frozen=True)
class SchedRecord:
    """One loop turn's token accounting (the invariant-test surface)."""

    step: int
    decode_tokens: int        # active decode slots charged this turn
    chunk_tokens: int         # prefill tokens granted this turn
    finish_tokens: int        # same-turn first-decode charges (one per
                              # prompt whose final chunk lands this turn)
    n_prefilling: int         # prefilling slots that wanted a chunk
    budget: int


class ChunkScheduler:
    """Round-robin chunk planner over the prefilling slots (host logic only).

    Stateless but for the rotation pointer and the accounting log — the
    engine owns all request/slot/block state; this class only answers
    "who chunks this turn, and by how much".
    """

    def __init__(self, cfg: SchedulerConfig, max_slots: int):
        cfg.validate(max_slots)
        self.cfg = cfg
        self._rr = 0
        self.records: list[SchedRecord] = []

    def plan(self, step: int, n_decode: int,
             prefilling: list[tuple[int, int]]) -> list[tuple[int, int]]:
        """-> ``[(slot_id, n_tokens)]`` chunks to run this turn.

        ``prefilling``: ``(slot_id, remaining_head_tokens)`` per slot still
        mid-prefill; ``n_decode``: decode slots stepping this turn (charged
        first — decode never waits on prefill).
        """
        quota = max(0, self.cfg.step_token_budget - n_decode)
        plan: list[tuple[int, int]] = []
        finish = 0
        if prefilling:
            start = self._rr % len(prefilling)
            order = prefilling[start:] + prefilling[:start]
            for slot_id, remaining in order:
                n = min(self.cfg.prefill_chunk, remaining)
                cost = n + (n == remaining)   # final chunk: +1 same-turn decode
                if 0 < n and cost <= quota:
                    plan.append((slot_id, n))
                    finish += n == remaining
                    quota -= cost
            self._rr += 1
        self.records.append(SchedRecord(
            step=step, decode_tokens=n_decode,
            chunk_tokens=sum(n for _, n in plan), finish_tokens=finish,
            n_prefilling=len(prefilling), budget=self.cfg.step_token_budget))
        return plan
