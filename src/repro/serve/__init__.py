from .engine import Request, ServeEngine  # noqa: F401
from .sampling import sample  # noqa: F401
