from .engine import Request, ServeEngine  # noqa: F401
from .lifecycle import (LifecycleError, RequestLifecycle,  # noqa: F401
                        RequestState, ShedPolicy, spec_ladder)
from .sampling import sample  # noqa: F401
from .scheduler import (ChunkScheduler, SchedRecord,  # noqa: F401
                        SchedulerConfig)
