"""Token sampling: greedy / temperature / top-k / top-p (jit-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def filtered_logits(logits: jax.Array, *, temperature: float, top_k: int = 0,
                    top_p: float = 1.0) -> jax.Array:
    """Temperature-scaled logits with -inf outside the top-k/top-p support.

    The distribution :func:`sample` actually draws from, exposed so the
    speculative accept/reject math (repro.spec.loop) can score draft and
    verify probabilities under EXACTLY the engine's sampling filters —
    temperature scaling, then top-k, then top-p, in that order.  Requires
    ``temperature > 0`` (greedy has no distribution to filter).
    """
    assert temperature > 0.0, "filtered_logits is for stochastic sampling"
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        # nucleus: keep the smallest prefix of the descending-prob ranking
        # whose mass reaches top_p; the first token always survives (the
        # max(..., 0) guard keeps top_p <= 0 maximally restrictive — i.e.
        # greedy — instead of wrapping kth to -1 and disabling the filter)
        sorted_desc = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_desc, axis=-1)
        cum_before = jnp.cumsum(probs, axis=-1) - probs
        keep = cum_before < top_p                       # (..., V) desc order
        kth = jnp.maximum(jnp.sum(keep, axis=-1) - 1, 0)  # last kept rank
        thr = jnp.take_along_axis(sorted_desc, kth[..., None], axis=-1)
        logits = jnp.where(logits < thr, -jnp.inf, logits)
    return logits


def sample(logits: jax.Array, key: jax.Array | None = None, *,
           temperature: float = 0.0, top_k: int = 0,
           top_p: float = 1.0) -> jax.Array:
    """logits (..., V) -> token ids (...,).  temperature==0 -> greedy.

    Filters compose in the standard order: temperature scaling, then top-k,
    then top-p (nucleus) over whatever support top-k left.  All ops are
    shape-static (sort/cumsum), so the function jits with ``temperature``,
    ``top_k`` and ``top_p`` as static arguments.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "sampling with temperature needs a PRNG key"
    logits = filtered_logits(logits, temperature=temperature, top_k=top_k,
                             top_p=top_p)
    flat = logits.reshape(-1, logits.shape[-1])
    keys = jax.random.split(key, flat.shape[0])
    toks = jax.vmap(jax.random.categorical)(keys, flat)
    return toks.reshape(logits.shape[:-1]).astype(jnp.int32)
