"""Token sampling: greedy / temperature / top-k (jit-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key: jax.Array | None = None, *,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits (..., V) -> token ids (...,).  temperature==0 -> greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert key is not None, "sampling with temperature needs a PRNG key"
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    flat = logits.reshape(-1, logits.shape[-1])
    keys = jax.random.split(key, flat.shape[0])
    toks = jax.vmap(jax.random.categorical)(keys, flat)
    return toks.reshape(logits.shape[:-1]).astype(jnp.int32)
