"""Sigma-driven state bitwidth allocation (DESIGN.md §11).

The decode state gets the same treatment the weights got: enumerate its
quantizable surface as ``LayerInfo`` entries (``kind="state"``), collect
sigma/KL robustness statistics over *calibration decodes*, and let the
existing two-phase controller (core/controller.py) allocate heterogeneous
per-layer K/V bitwidths under a ``state_bytes`` budget.

Naming convention (mirrors quant/apply's weight names):

  * decoder families (dense/moe/vlm):  ``layer{i:03d}.state.k`` / ``.v``
  * hybrid shared-attention caches:    ``shared_attn.app{j:03d}.state.k`` / ``.v``

K and V are independent entries — V (no RoPE structure) is routinely more
robust than K, and the statistics surface exactly that asymmetry.

The calibration environment the controller drives (``KVQuantEnv``) lives in
``kvcache/env.py`` — imported on demand so this module (and the models that
dispatch on ``QuantizedKVLayer``) stays free of the training-stack imports.
"""
from __future__ import annotations

from typing import Any

from repro.core.policy import BitPolicy, LayerInfo, PolicyArtifact, layer_registry_hash

from .cache import QuantizedKVLayer
from .paged import PagedKVLayer

#: families whose decode state has quantizable KV entries
KV_FAMILIES = ("dense", "moe", "vlm", "hybrid")


def kv_entry_names(cfg) -> list[str]:
    """Ordered names of the KV entries the family's decode state carries."""
    if cfg.family in ("dense", "moe", "vlm"):
        return [f"layer{i:03d}" for i in range(cfg.n_layers)]
    if cfg.family == "hybrid":
        from repro.models.hybrid import n_attn_applications
        return [f"shared_attn.app{j:03d}" for j in range(n_attn_applications(cfg))]
    return []


def state_layer_infos(cfg, batch: int, seq: int, *,
                      allocated_tokens: int | None = None) -> tuple[LayerInfo, ...]:
    """The quantizable decode-state surface for a serving geometry.

    Shape is the full multi-slot cache ``(batch, seq, n_kv, hd)`` so that
    ``BitPolicy.state_bytes()`` prices exactly what the engine allocates;
    macs are the per-decode-step attention MACs that read the entry
    (QK for .k, PV for .v), which is what the roofline FLOPs term wants.

    ``allocated_tokens`` prices a *paged* deployment instead (DESIGN.md
    §12): the shape collapses to ``(1, allocated_tokens, n_kv, hd)`` — the
    expected live block coverage rather than the dense worst case — so a
    ``state_bytes`` budget (and the roofline's per-step state traffic)
    bounds allocated blocks, not the ``batch * seq`` over-provisioning the
    paged pool exists to avoid.  Callers round to block granularity.  The
    geometry-independent ``state_surface_hash`` is unaffected.
    """
    hd = cfg.resolved_head_dim
    if allocated_tokens is not None:
        shape = (1, int(allocated_tokens), cfg.n_kv_heads, hd)
    else:
        shape = (batch, seq, cfg.n_kv_heads, hd)
    macs = batch * cfg.n_heads * seq * hd
    infos = [LayerInfo(f"{nm}.state.{side}", shape, macs=macs, kind="state")
             for nm in kv_entry_names(cfg) for side in ("k", "v")]
    return tuple(sorted(infos, key=lambda l: l.name))


def state_surface_hash(layers) -> str:
    """Geometry-independent identity of a state registry.

    Strips batch/seq (deployment choices — an engine may legitimately serve
    with different slots/max_seq than the search priced) from each entry's
    shape, keeping ``(name, (n_kv, hd), kind)``: two deployments agree iff
    they expose the same KV entries with the same head geometry.  This is
    the check the engine enforces; ``PolicyArtifact.verify_state_layers``
    remains the strict geometry-inclusive variant.
    """
    canon = tuple(LayerInfo(l.name, tuple(l.shape[-2:]), 0, l.kind)
                  for l in layers)
    return layer_registry_hash(canon)


def state_bits_by_name(policy: BitPolicy) -> dict[str, tuple[int, int]]:
    """Policy -> entry-name -> (k_bits, v_bits)."""
    out: dict[str, tuple[int, int]] = {}
    for l in policy.state_layers():
        nm, _, side = l.name.rpartition(".state.")
        kb, vb = out.get(nm, (0, 0))
        out[nm] = (policy.bits[l.name], vb) if side == "k" else (kb, policy.bits[l.name])
    return out


def resolve_state_bits(spec, cfg) -> list[tuple[int, int]] | None:
    """Engine-facing: spec -> per-entry (k_bits, v_bits) list in entry order.

    ``spec`` may be None (fp state), an int (uniform), a BitPolicy over
    state entries, or a PolicyArtifact (its state_policy is used).
    """
    if spec is None:
        return None
    names = kv_entry_names(cfg)
    if not names:
        raise ValueError(f"family {cfg.family!r} has no quantizable KV state")
    if isinstance(spec, PolicyArtifact):
        spec = spec.state_policy
        if spec is None:
            return None
    if isinstance(spec, int):
        return [(spec, spec)] * len(names)
    if isinstance(spec, BitPolicy):
        by_name = state_bits_by_name(spec)
        missing = [nm for nm in names if nm not in by_name]
        if missing:
            raise ValueError(f"state policy missing KV entries: {missing[:4]}")
        return [by_name[nm] for nm in names]
    raise TypeError(f"cannot resolve state bits from {type(spec).__name__}")


# ---------------------------------------------------------------------------
# deployment-side verification (the state analogue of quant/apply's
# packed_policy_bits / verify_packed_bits)
# ---------------------------------------------------------------------------


def extract_kv_entries(state) -> list[tuple[str, Any]]:
    """Ordered (entry-name, node) pairs of a decode-state pytree's KV slots.

    Works on fp states (nodes are ``{"k", "v"}`` dicts) and on quantized
    states, dense (``QuantizedKVLayer``) or paged (``PagedKVLayer``); SSM
    entries are skipped.
    """
    if isinstance(state, dict) and "attn" in state:  # hybrid
        return [(f"shared_attn.app{j:03d}", e) for j, e in enumerate(state["attn"])]
    if isinstance(state, (list, tuple)):
        out = []
        for i, e in enumerate(state):
            if isinstance(e, (QuantizedKVLayer, PagedKVLayer)) or (
                    isinstance(e, dict) and set(e) == {"k", "v"}):
                out.append((f"layer{i:03d}", e))
        return out
    return []


def packed_state_bits(state) -> dict[str, int]:
    """State-entry name -> bits actually packed into a decode-state pytree."""
    out: dict[str, int] = {}
    for nm, node in extract_kv_entries(state):
        if isinstance(node, (QuantizedKVLayer, PagedKVLayer)):
            out[f"{nm}.state.k"] = node.k_bits
            out[f"{nm}.state.v"] = node.v_bits
    return out


def verify_state_bits(state, artifact: PolicyArtifact, *,
                      surface=None) -> None:
    """Assert a decode state carries exactly the artifact's state bitwidths.

    Bidirectional like the weight check: a cache packed at the wrong width
    fails, and so does a searched state entry that was left fp.  Pass the
    deployment's ``state_layer_infos`` as ``surface`` to additionally
    reject an artifact searched on a different state surface (same bits,
    different head geometry) via the geometry-independent hash.
    """
    packed = packed_state_bits(state)
    if artifact.state_policy is not None and surface is not None:
        want = state_surface_hash(artifact.state_policy.layers)
        got = state_surface_hash(surface)
        if want != got:
            raise ValueError(
                f"policy artifact state-surface mismatch: artifact was "
                f"searched on {want}, this deployment exposes {got} "
                f"(different KV entries or head geometry)")
    if artifact.state_policy is None:
        if packed:
            raise ValueError(
                f"decode state is quantized ({len(packed)} entries) but the "
                f"policy artifact carries no state policy")
        return
    want = artifact.state_policy.bits
    wrong = {n: (b, want.get(n)) for n, b in packed.items() if want.get(n) != b}
    if wrong:
        sample = dict(list(wrong.items())[:4])
        raise ValueError(
            f"decode-state bitwidths disagree with the policy artifact on "
            f"{len(wrong)} entries (packed, artifact): {sample}")
    missing = sorted(set(want) - set(packed))
    if missing:
        raise ValueError(
            f"{len(missing)} searched state entries are not quantized in the "
            f"decode state (fp cache?): {missing[:4]}")
