# Heterogeneous quantized decode-state subsystem (DESIGN.md §11): packed
# per-layer K/V caches with block-wise scales, sigma-driven state bitwidth
# allocation, and the search/artifact/serve plumbing around them.
from .cache import (  # noqa: F401
    DEFAULT_BLOCK,
    QuantizedKVLayer,
    append_token,
    init_kv_layer,
    insert_rows,
    insert_state_rows,
    quantize_kv_rows,
)
from .paged import (  # noqa: F401
    BlockPool,
    PagedKVLayer,
    init_paged_layer,
    pool_blocks_for_budget,
)
from .policy import (  # noqa: F401
    kv_entry_names,
    packed_state_bits,
    resolve_state_bits,
    state_bits_by_name,
    state_layer_infos,
    state_surface_hash,
    verify_state_bits,
)

# KVQuantEnv (kvcache/env.py) is intentionally NOT imported here: it pulls
# in the training stack, which serve/model modules must stay free of.
