"""Paged quantized KV-cache: block pool + per-request block tables (DESIGN.md §12).

The dense ``QuantizedKVLayer`` allocates one ``(max_slots, max_seq)``
container per layer, so a 32-token request pays for the full ``max_seq`` of
sigma-budgeted state.  The paged design splits the cache into physical
*blocks* of ``block`` sequence positions — exactly the per-(slot, head,
seq-block) scale granularity the dense layout already quantizes at — and
maps them on demand:

  * ``PagedKVLayer`` holds one packed int-lane **pool** per layer per side
    (``(P, H, block, hd/lanes)`` int8 + ``(P, H, 1, 1)`` f32 scales) and a
    per-slot ``block_table`` ``(B, max_seq/block)`` int32 mapping logical
    sequence blocks to physical pool blocks (``-1`` = unmapped).  Physical
    block 0 is reserved as the *trash block*: idle slots' lockstep appends
    land there (clamped from ``-1``) so they can never corrupt live state.
  * ``BlockPool`` is the host-side allocator: LIFO free list + per-block
    refcounts.  Shared-prefix admission maps the same physical blocks into
    several slots (refcount > 1); the first append into a shared block
    copies it first (copy-on-write, serve/engine.py).
  * The block-table kernels live in ``kernels/quant_kv`` behind the same
    ``auto/pallas/xla/interpret`` dispatch as the dense ops — attention
    scalar-prefetches the table row and DMAs only mapped blocks.

Content parity with the dense layout is *bitwise*: blocks quantize with the
same ``_block_quantize`` / append-requant math, so a paged cache holding the
same rows as a dense cache produces bit-identical attention output — the
invariant ``tests/test_paged_kvcache.py`` pins and the serve engine's
dense-vs-paged token equality rides on.

Zero-beyond-write carries over: a freshly mapped block is fully overwritten
by its first write (prefill insertion quantizes whole blocks; appends zero
every position past the write offset), and attention zero-fills unmapped
table entries, so a freed block's previous occupant can never leak into a
later request — even across free -> realloc.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing

from .cache import (DEFAULT_BLOCK, _block_quantize, requantize_block,
                    resolve_block)

#: physical block id 0 is never allocated: it absorbs idle-slot appends
TRASH_BLOCK = 0


@dataclasses.dataclass
class PagedKVLayer:
    """One attention layer's paged packed decode state (pytree)."""

    k_packed: jax.Array     # int8 (P, H, block, hd/lanes_k) — the K pool
    k_scale: jax.Array      # f32  (P, H, 1, 1) — one scale per (block, head)
    v_packed: jax.Array     # int8 (P, H, block, hd/lanes_v)
    v_scale: jax.Array      # f32  (P, H, 1, 1)
    block_table: jax.Array  # int32 (B, max_seq/block); -1 = unmapped
    k_bits: int             # static
    v_bits: int             # static
    block: int              # static
    shape: tuple[int, ...]  # static logical (B, max_seq, H, hd)

    @property
    def seq(self) -> int:
        return self.shape[1]

    @property
    def head_dim(self) -> int:
        return self.shape[3]

    @property
    def num_blocks(self) -> int:
        return self.k_packed.shape[0]

    def bytes_per_block(self) -> int:
        """Packed + scale bytes ONE physical block occupies (both sides)."""
        _, _, h, hd = self.shape
        packed = sum(packing.container_bytes((h, self.block, hd), bits)
                     for bits in (self.k_bits, self.v_bits))
        return packed + 2 * 4 * h  # two f32 scales per (block, head)

    def container_bytes(self) -> int:
        """Whole-pool footprint in HBM (incl. the block table)."""
        return self.num_blocks * self.bytes_per_block() + 4 * self.block_table.size

    def allocated_bytes(self, n_blocks: int) -> int:
        """Footprint of ``n_blocks`` live blocks — what the budget prices."""
        return n_blocks * self.bytes_per_block()


jax.tree_util.register_dataclass(
    PagedKVLayer,
    data_fields=["k_packed", "k_scale", "v_packed", "v_scale", "block_table"],
    meta_fields=["k_bits", "v_bits", "block", "shape"],
)


def init_paged_layer(num_blocks: int, slots: int, max_seq: int, n_kv: int,
                     hd: int, *, k_bits: int, v_bits: int,
                     block: int = DEFAULT_BLOCK) -> PagedKVLayer:
    """All-unmapped paged cache with ``num_blocks`` physical blocks (+ trash).

    ``num_blocks`` counts *usable* blocks; the reserved trash block is added
    on top so a budget of N blocks really buys N blocks of live state.
    """
    packing.check_bits(k_bits)
    packing.check_bits(v_bits)
    block = resolve_block(max_seq, block)
    if num_blocks < 1:
        raise ValueError(f"pool needs at least one usable block, got {num_blocks}")
    p = num_blocks + 1  # + trash
    mk = lambda bits: jnp.zeros((p, n_kv, block, -(-hd // packing.LANES[bits])),
                                jnp.int8)
    sc = lambda: jnp.full((p, n_kv, 1, 1), 1e-12, jnp.float32)
    table = jnp.full((slots, max_seq // block), -1, jnp.int32)
    return PagedKVLayer(k_packed=mk(k_bits), k_scale=sc(), v_packed=mk(v_bits),
                        v_scale=sc(), block_table=table, k_bits=int(k_bits),
                        v_bits=int(v_bits), block=block,
                        shape=(slots, max_seq, n_kv, hd))


def pool_blocks_for_budget(state_bits: list[tuple[int, int]], n_kv: int,
                           hd: int, block: int, budget_bytes: float) -> int:
    """Max usable physical blocks a ``state_bytes`` budget buys.

    One "block" here is one *logical* block across every layer (the
    allocator hands out the same physical id in each layer's pool), so the
    per-block price sums the per-layer K+V packed lanes and scales.
    """
    per_block = 0
    for kb, vb in state_bits:
        per_block += sum(packing.container_bytes((n_kv, block, hd), bits)
                         for bits in (kb, vb))
        per_block += 2 * 4 * n_kv
    n = int(budget_bytes // per_block)
    if n < 1:
        raise ValueError(
            f"state_bytes budget {budget_bytes:g} buys zero blocks "
            f"({per_block} B/block across {len(state_bits)} layers)")
    return n


# ---------------------------------------------------------------------------
# host-side allocator
# ---------------------------------------------------------------------------


class BlockPool:
    """Free-list block allocator with refcounts (host side, not a pytree).

    Physical ids are shared across every layer's pool buffers — one
    allocation maps the same id in all layers.  Refcounts > 1 mark blocks
    mapped into several slots (shared prefixes); ``decref`` returns a block
    to the free list only when its last reference drops.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError("BlockPool needs at least one usable block")
        self.num_blocks = num_blocks
        # LIFO free list over usable ids [1, num_blocks]; 0 is the trash block
        self._free = list(range(num_blocks, TRASH_BLOCK, -1))
        self._ref = np.zeros(num_blocks + 1, np.int32)
        self.reserved = 0  # blocks promised to admitted requests' future growth
        self.peak_allocated = 0
        self.cow_copies = 0
        self.shared_maps = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def available(self) -> int:
        """Free blocks not already promised to an admitted request."""
        return len(self._free) - self.reserved

    @property
    def allocated(self) -> int:
        return self.num_blocks - len(self._free)

    def reserve(self, n: int) -> None:
        """Promise ``n`` future blocks (admission-time growth accounting:
        every admitted request's decode appends and copy-on-write splits are
        pre-counted, so a mid-decode allocation can never strand it)."""
        assert n <= self.available, (n, self.available)
        self.reserved += n

    def unreserve(self, n: int) -> None:
        assert n <= self.reserved, (n, self.reserved)
        self.reserved -= n

    def refcount(self, bid: int) -> int:
        return int(self._ref[bid])

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"block pool exhausted ({self.num_blocks} blocks allocated); "
                f"raise the state_bytes budget / pool_blocks or admit fewer "
                f"concurrent requests")
        bid = self._free.pop()
        self._ref[bid] = 1
        self.peak_allocated = max(self.peak_allocated, self.allocated)
        return bid

    def incref(self, bid: int) -> int:
        assert bid != TRASH_BLOCK and self._ref[bid] > 0, bid
        self._ref[bid] += 1
        self.shared_maps += 1
        return bid

    def decref(self, bid: int) -> None:
        if bid == TRASH_BLOCK:
            return
        assert self._ref[bid] > 0, f"double free of block {bid}"
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)


# ---------------------------------------------------------------------------
# dense view (reference path + tests)
# ---------------------------------------------------------------------------


def to_dense(layer: PagedKVLayer):
    """Gather the paged pool into the dense ``QuantizedKVLayer`` layout.

    Mapped blocks gather their pool bytes; unmapped positions read as zero
    levels with the init scale — exactly what a dense cache holds where
    nothing was written.  This makes the xla/interpret paged attention
    *bitwise* equal to the dense path on identical contents.
    """
    from .cache import QuantizedKVLayer

    b, s, h, hd = layer.shape
    nb = s // layer.block
    tbl = layer.block_table                              # (B, nb)
    mapped = (tbl >= 0)[:, :, None, None, None]          # (B, nb, 1, 1, 1)
    idx = jnp.maximum(tbl, 0)

    def side(pool, scale):
        blk = jnp.take(pool, idx, axis=0)                # (B, nb, H, block, hdp)
        blk = jnp.where(mapped, blk, jnp.int8(0))
        packed = jnp.moveaxis(blk, 2, 1).reshape(b, h, s, pool.shape[-1])
        sc = jnp.take(scale[..., 0, 0], idx, axis=0)     # (B, nb, H)
        sc = jnp.where(mapped[..., 0, 0, 0][..., None], sc, 1e-12)
        return packed, jnp.moveaxis(sc, 2, 1)[..., None]  # (B, H, nb, 1)

    kp, ks = side(layer.k_packed, layer.k_scale)
    vp, vs = side(layer.v_packed, layer.v_scale)
    return QuantizedKVLayer(k_packed=kp, k_scale=ks, v_packed=vp, v_scale=vs,
                            k_bits=layer.k_bits, v_bits=layer.v_bits,
                            block=layer.block, shape=layer.shape)


# ---------------------------------------------------------------------------
# prefill insertion (engine admission)
# ---------------------------------------------------------------------------


def insert_prefill_rows(layer: PagedKVLayer, row_tables, k_new: jax.Array,
                        v_new: jax.Array,
                        valid_len: jax.Array | None = None) -> PagedKVLayer:
    """Quantize fp prefill rows ``(N, P, H, hd)`` into their mapped blocks.

    ``row_tables`` is ``(N, ceil(P/block))`` int32 of *physical* destination
    ids per (row, logical block); entries < 0 skip the write (shared-prefix
    blocks a donor slot already holds, or pad blocks past the row's
    coverage) by redirecting the scatter to the trash block.  Quantization
    is the dense path's ``_block_quantize`` — identical rows produce
    bit-identical blocks, which is what makes prefix sharing exact.
    """
    n, p, h, hd = k_new.shape
    pad = (-p) % layer.block
    if pad:
        zeros = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_new = jnp.pad(k_new.astype(jnp.float32), zeros)
        v_new = jnp.pad(v_new.astype(jnp.float32), zeros)
        p += pad
    npb = p // layer.block
    row_tables = jnp.asarray(row_tables, jnp.int32)
    if row_tables.shape != (n, npb):
        raise ValueError(f"row_tables {row_tables.shape} != {(n, npb)}")
    dest = jnp.maximum(row_tables, TRASH_BLOCK).reshape(-1)  # (N*npb,)

    kh = jnp.swapaxes(k_new, 1, 2).astype(jnp.float32)       # (N, H, P, hd)
    vh = jnp.swapaxes(v_new, 1, 2).astype(jnp.float32)
    if valid_len is not None:
        keep = (jnp.arange(p) < valid_len[:, None])[:, None, :, None]
        kh = jnp.where(keep, kh, 0.0)
        vh = jnp.where(keep, vh, 0.0)

    def side(pool, scale, x, bits):
        packed, sc = _block_quantize(x, bits, layer.block)   # (N,H,P,hdp), (N,H,nb,1)
        blk = packed.reshape(n, h, npb, layer.block, -1)
        blk = jnp.moveaxis(blk, 2, 1).reshape(n * npb, h, layer.block, -1)
        scb = jnp.moveaxis(sc, 2, 1).reshape(n * npb, h, 1, 1)
        return pool.at[dest].set(blk), scale.at[dest].set(scb)

    kp, ks = side(layer.k_packed, layer.k_scale, kh, layer.k_bits)
    vp, vs = side(layer.v_packed, layer.v_scale, vh, layer.v_bits)
    return dataclasses.replace(layer, k_packed=kp, k_scale=ks,
                               v_packed=vp, v_scale=vs)


def append_token_paged(layer: PagedKVLayer, pos: jax.Array, k_new: jax.Array,
                       v_new: jax.Array) -> PagedKVLayer:
    """Write one decode token per slot into its mapped block (jnp reference).

    ``k_new``/``v_new``: fp ``(B, 1, H, hd)``; ``pos``: () or (B,) int32.
    The engine guarantees the target block of every *active* slot is mapped
    and exclusively owned (copy-on-write happens host-side before the
    step); idle slots' tables read ``-1`` and clamp to the trash block.
    """
    b = k_new.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    bidx = pos // layer.block
    phys = jnp.maximum(
        jnp.take_along_axis(layer.block_table, bidx[:, None], axis=1)[:, 0],
        TRASH_BLOCK)                                          # (B,)
    off = pos % layer.block
    kh = jnp.swapaxes(k_new, 1, 2)[:, :, 0].astype(jnp.float32)  # (B, H, hd)
    vh = jnp.swapaxes(v_new, 1, 2)[:, :, 0].astype(jnp.float32)

    def side(pool, scale, new, bits):
        blk = jnp.take(pool, phys, axis=0)                    # (B, H, block, hdp)
        sc = jnp.take(scale, phys, axis=0)                    # (B, H, 1, 1)
        lev = packing.unpack(blk, bits, layer.head_dim)
        fp = lev.astype(jnp.float32) * sc
        blk_new, sc_new = requantize_block(fp, new, off, bits)
        return pool.at[phys].set(blk_new), scale.at[phys].set(sc_new)

    kp, ks = side(layer.k_packed, layer.k_scale, kh, layer.k_bits)
    vp, vs = side(layer.v_packed, layer.v_scale, vh, layer.v_bits)
    return dataclasses.replace(layer, k_packed=kp, k_scale=ks,
                               v_packed=vp, v_scale=vs)


def copy_blocks(layer: PagedKVLayer, src: jax.Array, dst: jax.Array) -> PagedKVLayer:
    """Device-copy pool blocks ``src -> dst`` in every buffer (copy-on-write)."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    cp = lambda buf: buf.at[dst].set(jnp.take(buf, src, axis=0))
    return dataclasses.replace(layer, k_packed=cp(layer.k_packed),
                               k_scale=cp(layer.k_scale),
                               v_packed=cp(layer.v_packed),
                               v_scale=cp(layer.v_scale))


def with_table(layer: PagedKVLayer, table) -> PagedKVLayer:
    """Swap in a new host-built block table (admission / CoW / free)."""
    return dataclasses.replace(layer,
                               block_table=jnp.asarray(table, jnp.int32))
