"""Packed quantized KV-cache container for the decode state (DESIGN.md §11).

At long contexts and many slots the decode state, not the weights, dominates
edge memory: an fp32 cache spends ``4 * B * S * H * hd`` bytes per layer per
side.  ``QuantizedKVLayer`` stores the same state as SigmaQuant-packed int
lanes (``core/packing``) plus per-block scales:

  * ``*_packed``  int8 ``(B, H, S, hd/lanes)`` — head-major, packed along
    ``hd`` (the attention contraction axis), so a row unpacks into the
    contiguous head_dim the QK/PV dots consume — the same lane layout the
    weight kernels use.
  * ``*_scale``   f32 ``(B, H, S/block, 1)`` — one symmetric scale per
    (slot, head, sequence-block) group.  Blocking along the *sequence* axis
    means a decode append touches exactly one block: the current block is
    dequantized, the new token inserted, and the block requantized under a
    fresh scale — every other block's bytes and scales are untouched.

Invariant: packed levels at positions >= the slot's write position are zero
(appends mask them, prefill insertion zero-fills beyond the valid length),
so a freshly entered block never inherits a stale occupant's amax and the
dequantized cache is exactly zero wherever ``kv_valid`` masks anyway.

K and V carry independent bitwidths (``k_bits`` / ``v_bits``): V has no
RoPE structure and is routinely more robust, which is exactly the kind of
asymmetry the sigma/KL statistics surface and the ``StateBitPolicy``
exploits (kvcache/policy.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import packing, quantizer

#: sequence-axis scale-block length (one append requantizes one block)
DEFAULT_BLOCK = 16


@dataclasses.dataclass
class QuantizedKVLayer:
    """One attention layer's packed decode state (pytree; bits/shape static)."""

    k_packed: jax.Array   # int8 (B, H, S, hd/lanes_k)
    k_scale: jax.Array    # f32  (B, H, S/block, 1)
    v_packed: jax.Array   # int8 (B, H, S, hd/lanes_v)
    v_scale: jax.Array    # f32  (B, H, S/block, 1)
    k_bits: int           # static
    v_bits: int           # static
    block: int            # static
    shape: tuple[int, ...]  # static logical (B, S, H, hd)

    @property
    def seq(self) -> int:
        return self.shape[1]

    @property
    def head_dim(self) -> int:
        return self.shape[3]

    def container_bytes(self) -> int:
        """Packed + scale bytes this layer's state occupies in HBM."""
        b, s, h, hd = self.shape
        packed = sum(packing.container_bytes((b, h, s, hd), bits)
                     for bits in (self.k_bits, self.v_bits))
        return packed + 4 * (self.k_scale.size + self.v_scale.size)

    def dequantize(self, dtype=jnp.float32) -> tuple[jax.Array, jax.Array]:
        """Back to float ``(k, v)`` each ``(B, S, H, hd)`` (reference path)."""
        k = _dequant_side(self.k_packed, self.k_scale, self.k_bits,
                          self.head_dim, self.block)
        v = _dequant_side(self.v_packed, self.v_scale, self.v_bits,
                          self.head_dim, self.block)
        swap = lambda x: jnp.swapaxes(x, 1, 2).astype(dtype)  # (B,H,S,hd)->(B,S,H,hd)
        return swap(k), swap(v)


jax.tree_util.register_dataclass(
    QuantizedKVLayer,
    data_fields=["k_packed", "k_scale", "v_packed", "v_scale"],
    meta_fields=["k_bits", "v_bits", "block", "shape"],
)


def resolve_block(seq: int, block: int = DEFAULT_BLOCK) -> int:
    """Largest divisor of ``seq`` that is <= the requested block length."""
    for d in range(min(block, seq), 0, -1):
        if seq % d == 0:
            return d
    return 1


def init_kv_layer(batch: int, seq: int, n_kv: int, hd: int, *, k_bits: int,
                  v_bits: int, block: int = DEFAULT_BLOCK) -> QuantizedKVLayer:
    """All-zero packed cache for ``batch`` slots of ``seq`` positions."""
    packing.check_bits(k_bits)
    packing.check_bits(v_bits)
    block = resolve_block(seq, block)
    nb = seq // block
    mk = lambda bits: jnp.zeros((batch, n_kv, seq, -(-hd // packing.LANES[bits])),
                                jnp.int8)
    # distinct scale buffers: K and V may be donated side by side in one step
    sc = lambda: jnp.full((batch, n_kv, nb, 1), 1e-12, jnp.float32)
    return QuantizedKVLayer(k_packed=mk(k_bits), k_scale=sc(), v_packed=mk(v_bits),
                            v_scale=sc(), k_bits=int(k_bits), v_bits=int(v_bits),
                            block=block, shape=(batch, seq, n_kv, hd))


# ---------------------------------------------------------------------------
# block quantization primitives (pure jnp: jit/vmap/donation friendly)
# ---------------------------------------------------------------------------


def _block_quantize(x: jax.Array, bits: int, block: int):
    """fp ``(..., S, hd)`` -> packed ``(..., S, hd/lanes)`` + scale ``(..., S/block, 1)``.

    Symmetric per-(block x hd) group: scale = amax / qmax (core/quantizer
    scheme), levels clipped to the signed b-bit grid and lane-packed along hd.
    """
    *lead, s, hd = x.shape
    nb = s // block
    xb = x.astype(jnp.float32).reshape(*lead, nb, block, hd)
    amax = jnp.max(jnp.abs(xb), axis=(-1, -2), keepdims=True)  # (..., nb, 1, 1)
    scale = jnp.maximum(amax, 1e-12) / quantizer.qmax(bits)
    q = quantizer.qmax(bits)
    lev = jnp.clip(jnp.round(xb / scale), -q, q).astype(jnp.int32)
    packed = packing.pack(lev.reshape(*lead, s, hd), bits)
    return packed, scale[..., 0, :]  # (..., nb, 1)


def _dequant_side(packed: jax.Array, scale: jax.Array, bits: int, hd: int,
                  block: int) -> jax.Array:
    """Inverse of :func:`_block_quantize` on the (B, H, S, ·) layout."""
    lev = packing.unpack(packed, bits, hd)                     # (B, H, S, hd)
    *lead, s, _ = lev.shape
    nb = s // block
    fp = lev.astype(jnp.float32).reshape(*lead, nb, block, hd) * scale[..., None]
    return fp.reshape(*lead, s, hd)


def quantize_kv_rows(k: jax.Array, v: jax.Array, layer: QuantizedKVLayer,
                     valid_len: jax.Array | None = None):
    """Quantize fp prefill rows ``(N, P, H, hd)`` into this layer's format.

    ``valid_len`` (N,) zeroes positions >= each row's true prompt length
    before scales are computed (the container invariant: invalid positions
    hold zero levels and never inflate a block's amax).  ``P`` must be a
    multiple of ``layer.block`` (callers round the prefill pad up).
    """
    kh = jnp.swapaxes(k, 1, 2).astype(jnp.float32)  # (N, H, P, hd)
    vh = jnp.swapaxes(v, 1, 2).astype(jnp.float32)
    if valid_len is not None:
        keep = (jnp.arange(k.shape[1]) < valid_len[:, None])[:, None, :, None]
        kh = jnp.where(keep, kh, 0.0)
        vh = jnp.where(keep, vh, 0.0)
    kp, ks = _block_quantize(kh, layer.k_bits, layer.block)
    vp, vs = _block_quantize(vh, layer.v_bits, layer.block)
    return kp, ks, vp, vs


def insert_rows(layer: QuantizedKVLayer, ids: jax.Array, k_new: jax.Array,
                v_new: jax.Array, valid_len: jax.Array | None = None) -> QuantizedKVLayer:
    """Scatter quantized prefill rows into slots ``ids`` (engine admission).

    ``k_new``/``v_new``: fp ``(N, P, H, hd)`` from the batched prefill; ``P``
    is rounded up to a block multiple here (extra positions zero-filled).
    """
    n, p, h, hd = k_new.shape
    pad = (-p) % layer.block
    if pad:
        zeros = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_new = jnp.pad(k_new.astype(jnp.float32), zeros)
        v_new = jnp.pad(v_new.astype(jnp.float32), zeros)
        p += pad
    if p > layer.seq:
        raise ValueError(f"prefill rows ({p}) exceed cache seq ({layer.seq})")
    kp, ks, vp, vs = quantize_kv_rows(k_new, v_new, layer, valid_len)

    def scatter(buf, new):
        idx = (ids,) + tuple(slice(0, d) for d in new.shape[1:])
        return buf.at[idx].set(new.astype(buf.dtype))

    return dataclasses.replace(
        layer,
        k_packed=scatter(layer.k_packed, kp), k_scale=scatter(layer.k_scale, ks),
        v_packed=scatter(layer.v_packed, vp), v_scale=scatter(layer.v_scale, vs))


def insert_state_rows(state, ids: jax.Array, st_new, valid_len: jax.Array):
    """Tree-insert rows of a batched prefill state into a decode state.

    The ONE walker both the serve engine's admission and the calibration
    env share: ``QuantizedKVLayer`` nodes quantize the fp prefill rows
    block-wise on the way in (``valid_len`` zeroes positions beyond each
    row's true prompt length), fp leaves scatter directly — one scatter per
    leaf, row ``i`` of the prefill batch landing in slot ``ids[i]``.
    """

    def walk(st, new):
        if isinstance(st, QuantizedKVLayer):
            return insert_rows(st, ids, new["k"], new["v"], valid_len=valid_len)
        if isinstance(st, dict):
            return {k: walk(st[k], new[k]) for k in st}
        if isinstance(st, (list, tuple)):
            return [walk(s, n) for s, n in zip(st, new)]
        idx = (ids,) + tuple(slice(0, d) for d in jnp.shape(new)[1:])
        return st.at[idx].set(new.astype(st.dtype))

    return walk(state, st_new)


def requantize_block_levels(blk_fp: jax.Array, new: jax.Array, off: jax.Array,
                            bits: int):
    """:func:`requantize_block` stopping at the integer levels (pre-pack).

    The fused decode-step path (kernels/quant_kv) consumes the ``(B, H,
    block, hd)`` int32 levels directly — attention can substitute them into
    the unpacked cache without a pack->unpack round trip, bit-identically
    (pack/unpack is exact on the clipped signed grid).
    """
    q = quantizer.qmax(bits)
    idx = jnp.arange(blk_fp.shape[2])[None, None, :, None]
    offb = off[:, None, None, None]
    fp = jnp.where(idx < offb, blk_fp, 0.0)
    fp = jnp.where(idx == offb, new.astype(jnp.float32)[:, :, None, :], fp)
    amax = jnp.max(jnp.abs(fp), axis=(2, 3), keepdims=True)    # (B, H, 1, 1)
    sc = jnp.maximum(amax, 1e-12) / q
    lev = jnp.clip(jnp.round(fp / sc), -q, q).astype(jnp.int32)
    return lev, sc


def requantize_block(blk_fp: jax.Array, new: jax.Array, off: jax.Array,
                     bits: int):
    """Insert ``new`` at ``off`` into a dequantized block and requantize.

    ``blk_fp``: f32 (B, H, block, hd); ``new``: (B, H, hd); ``off``: (B,).
    Positions > off zero out (container invariant), so a stale previous
    occupant can neither leak into attention nor inflate the fresh scale.

    THE single jnp source of the append-requant math: both the dense
    (:func:`_append_side`) and the paged (``paged.append_token_paged``)
    layouts call it, so their packed levels stay bit-identical — the
    invariant the engine's dense-vs-paged token equality and the paged
    shared-prefix scheme both ride on.  (The Pallas ``_append_kernel`` body
    is the kernel-side counterpart; the parity harness pins the two.)
    """
    lev, sc = requantize_block_levels(blk_fp, new, off, bits)
    return packing.pack(lev, bits), sc


def _append_side(packed: jax.Array, scale: jax.Array, new: jax.Array,
                 pos: jax.Array, bits: int, hd: int, block: int):
    """Requantize only the block containing ``pos`` with the new row inserted.

    ``new``: fp (B, H, hd); ``pos``: (B,) int32 per-slot write positions.

    Written as one gather (take_along_axis on the block axis) + dense math
    (:func:`requantize_block`) + one full-array select per buffer: per-slot
    dynamic-slice/scatter chains lower to gathers over tiny operands that
    dominate the decode step on the XLA fallback path, while the select
    fuses.
    """
    b, h, s, hdp = packed.shape
    nb = s // block
    bidx = pos // block                                        # (B,)
    off = pos % block
    view = packed.reshape(b, h, nb, block, hdp)
    blk = jnp.take_along_axis(view, bidx[:, None, None, None, None], axis=2)
    lev = packing.unpack(blk, bits, hd)[:, :, 0]               # (B, H, block, hd)
    sc_b = jnp.take_along_axis(scale, bidx[:, None, None, None], axis=2)
    fp = lev.astype(jnp.float32) * sc_b                        # (B, H, 1, 1) bc
    blk_new, sc_new = requantize_block(fp, new, off, bits)
    at_block = (jnp.arange(nb) == bidx[:, None])[:, None, :, None, None]
    packed2 = jnp.where(at_block, blk_new[:, :, None], view).reshape(b, h, s, hdp)
    scale2 = jnp.where(at_block[..., 0], sc_new, scale)
    return packed2, scale2


def append_token(layer: QuantizedKVLayer, pos: jax.Array, k_new: jax.Array,
                 v_new: jax.Array) -> QuantizedKVLayer:
    """Write one decode token's K/V at per-slot ``pos`` (jnp reference path).

    ``k_new``/``v_new``: fp ``(B, 1, H, hd)`` (the _qkv output).  The Pallas
    variant lives in ``kernels/quant_kv`` behind the same ops dispatch.
    """
    kh = jnp.swapaxes(k_new, 1, 2)[:, :, 0]  # (B, H, hd)
    vh = jnp.swapaxes(v_new, 1, 2)[:, :, 0]
    # scalar pos (lockstep batch) broadcasts to the per-slot vector form
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                           (k_new.shape[0],))
    kp, ks = _append_side(layer.k_packed, layer.k_scale, kh, pos,
                          layer.k_bits, layer.head_dim, layer.block)
    vp, vs = _append_side(layer.v_packed, layer.v_scale, vh, pos,
                          layer.v_bits, layer.head_dim, layer.block)
    return dataclasses.replace(layer, k_packed=kp, k_scale=ks,
                               v_packed=vp, v_scale=vs)
