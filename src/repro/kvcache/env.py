"""KVQuantEnv — the calibration environment for state-bitwidth search.

Prefills a calibration batch once, captures the fp K/V tensors every state
entry sees, and scores a candidate state policy (a ``BitPolicy`` over the
``kind="state"`` registry from kvcache/policy.py) by the logit divergence
of ONE quantized-state decode step against the fp-state step — a real
end-to-end fidelity measure that stays cheap enough for the controller's
inner loop.  Post-training path: ``calibrate_and_qat`` is a no-op.

Kept out of ``kvcache/__init__`` on purpose: it pulls in the training stack
(``quant.env``), which the serve/model modules that merely dispatch on
``QuantizedKVLayer`` must not import.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.policy import BitPolicy
from repro.quant.env import QuantEnvBase

from .cache import DEFAULT_BLOCK, insert_state_rows
from .policy import KV_FAMILIES, extract_kv_entries, resolve_state_bits, state_layer_infos


class KVQuantEnv(QuantEnvBase):
    """QuantEnv over the decode state of one served model.

    quality(policy) = -(mean |logits_quant - logits_fp| / mean |logits_fp|)
    of one decode step on calibration prompts: 0 is perfect state fidelity,
    and the budget's ``acc_t`` is minus the tolerated relative logit error.
    """

    def __init__(self, serve_params: dict, cfg, calib_tokens, *, slots: int,
                 max_seq: int, block: int = DEFAULT_BLOCK, cost_model=None,
                 qimpl: str = "auto", allocated_tokens: int | None = None):
        from repro.cost import ShiftAddCostModel
        from repro.models import registry

        if cfg.family not in KV_FAMILIES:
            raise ValueError(f"family {cfg.family!r} has no quantizable KV state")
        self.params = serve_params
        self.cfg = cfg
        self.block = block
        self.qimpl = qimpl
        self.cost_model = cost_model or ShiftAddCostModel()
        self._api = registry.get_api(cfg)
        # allocated_tokens: price a paged pool's live blocks instead of the
        # dense (slots, max_seq) worst case (DESIGN.md §12).  Fidelity is
        # still scored on a dense calibration cache — paged blocks hold
        # bit-identical contents, so the quality measure transfers exactly.
        self._specs = state_layer_infos(cfg, slots, max_seq,
                                        allocated_tokens=allocated_tokens)

        # one calibration prefill: capture the fp K/V every entry sees
        with self._span("calibrate", prompts=len(calib_tokens)):
            toks = jnp.asarray(calib_tokens, jnp.int32)
            bc, sc = toks.shape
            self._calib_batch, self._calib_len = bc, sc
            self._max_seq = max_seq
            _, caches = self._api.prefill(serve_params, cfg, tokens=toks, qimpl=qimpl)
            self._caches = caches
            self._capture = {}
            for nm, node in extract_kv_entries(caches):
                self._capture[f"{nm}.state.k"] = node["k"]
                self._capture[f"{nm}.state.v"] = node["v"]

            # fp-state reference step: replay the last calibration token at
            # the next position (exactly what the engine's decode step does)
            self._next_tok = toks[:, -1:]
            self._pos = jnp.full((bc,), sc, jnp.int32)
            self._fp_logits = self._decode_logits(state_policy=None)
            self._fp_scale = float(jnp.mean(jnp.abs(self._fp_logits))) or 1.0

    # -- state construction --------------------------------------------------
    def _build_state(self, state_policy: BitPolicy | None):
        bc, seq = self._calib_batch, self._max_seq
        bits = resolve_state_bits(state_policy, self.cfg)
        state = self._api.init_decode_state(self.cfg, bc, seq, jnp.float32,
                                            state_bits=bits, block=self.block)
        lens = jnp.full((bc,), self._calib_len, jnp.int32)
        return insert_state_rows(state, jnp.arange(bc), self._caches, lens)

    def _decode_logits(self, state_policy: BitPolicy | None):
        state = self._build_state(state_policy)
        logits, _ = self._api.decode_step(self.params, self.cfg, state,
                                          self._next_tok, self._pos,
                                          qimpl=self.qimpl)
        return logits[:, -1]

    # -- QuantEnv protocol ---------------------------------------------------
    def _weight(self, name: str):
        return self._capture[name]

    def evaluate(self, policy: BitPolicy) -> float:
        with self._span("evaluate"):
            lq = self._decode_logits(policy)
            return -float(jnp.mean(jnp.abs(lq - self._fp_logits))) / self._fp_scale

    def calibrate_and_qat(self, policy: BitPolicy, epochs: int) -> None:
        pass  # post-training: the packed state needs no retraining

    def fp_state_bytes(self) -> int:
        """fp32 cache bytes of the same geometry (the baseline the budget cuts)."""
        return int(sum(4 * np.prod(l.shape) for l in self._specs))
