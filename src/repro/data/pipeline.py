"""Deterministic, shard-aware synthetic token pipeline.

Offline container ⇒ no CIFAR/ImageNet; instead a *learnable* synthetic
language (DESIGN.md §9): a Zipfian unigram prior mixed with a deterministic
bigram permutation.  A model that learns the bigram table drives loss from
``log V`` down to the mixture entropy, so QAT/quantization stress is real.

Every batch is a pure function of ``(seed, step, host)`` — the pipeline is
stateless.  That buys, for free, the three properties a 1000-node fleet
needs from its input layer:

  * **checkpointable**: the restore state is one integer (``step``),
  * **elastic**: on a re-mesh, hosts re-slice the same global batch by
    their new ``(host_id, n_hosts)`` — no data is lost or duplicated,
  * **straggler-safe**: any host can recompute any other host's slice.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class TokenTask:
    """Synthetic language: ``next = perm[cur]`` w.p. 1-eps, else Zipf draw."""

    vocab_size: int
    seed: int = 0
    zipf_alpha: float = 1.2
    noise: float = 0.25          # eps: fraction of transitions drawn from the prior

    def _perm(self) -> jax.Array:
        return jax.random.permutation(jax.random.key(self.seed ^ 0x5EED), self.vocab_size)

    def _zipf_logits(self) -> jax.Array:
        ranks = jnp.arange(1, self.vocab_size + 1, dtype=jnp.float32)
        return -self.zipf_alpha * jnp.log(ranks)

    def sequence_batch(self, key: jax.Array, batch: int, seq_len: int) -> jax.Array:
        """(B, S+1) token stream — callers split into inputs/labels."""
        perm = self._perm()
        zl = self._zipf_logits()
        k0, k1, k2 = jax.random.split(key, 3)
        first = jax.random.categorical(k0, zl, shape=(batch,))
        noise_draws = jax.random.categorical(k1, zl, shape=(batch, seq_len))
        use_noise = jax.random.bernoulli(k2, self.noise, (batch, seq_len))

        def step(cur, xs):
            nz, un = xs
            nxt = jnp.where(un, nz, perm[cur])
            return nxt, nxt

        _, rest = jax.lax.scan(step, first, (noise_draws.T, use_noise.T))
        return jnp.concatenate([first[:, None], rest.T.astype(jnp.int32)], axis=1)

    def entropy_floor(self) -> float:
        """Per-token cross-entropy of the generating process (loss floor)."""
        import numpy as np

        p = np.exp(np.asarray(self._zipf_logits(), dtype=np.float64))
        p /= p.sum()
        h_prior = -(p * np.log(p)).sum()
        e = self.noise
        # optimal predictor knows perm: H = H(e) + e*H(zipf) (perm branch is deterministic)
        h_bern = -(e * np.log(max(e, 1e-12)) + (1 - e) * np.log(max(1 - e, 1e-12)))
        return float(h_bern + e * h_prior)


@dataclasses.dataclass(frozen=True)
class PipelineState:
    """The whole restore state of the input layer (checkpointed as one int)."""

    step: int = 0

    def next(self) -> "PipelineState":
        return PipelineState(self.step + 1)


def _batch_key(task: TokenTask, step: int) -> jax.Array:
    return jax.random.fold_in(jax.random.key(task.seed), step)


def global_batch(task: TokenTask, cfg: ArchConfig, shape: ShapeSpec, step: int) -> dict:
    """Full global batch at ``step`` (tokens/labels or embeds per family)."""
    key = _batch_key(task, step)
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_kind == "embeddings":
        kt, ke = jax.random.split(key)
        stream = task.sequence_batch(kt, b, s)
        # vlm/audio stub: frontend embeddings derived deterministically from tokens
        table = jax.random.normal(ke, (task.vocab_size, cfg.d_model)) * 0.02
        return {"embeds": table[stream[:, :-1]].astype(jnp.dtype(cfg.dtype)),
                "labels": stream[:, 1:]}
    if cfg.family in ("audio", "encdec"):
        kt, kf = jax.random.split(key)
        stream = task.sequence_batch(kt, b, s)
        frames = jax.random.normal(kf, (b, cfg.encoder_seq, cfg.d_model)) * 0.02
        return {"frames": frames.astype(jnp.dtype(cfg.dtype)),
                "tokens": stream[:, :-1], "labels": stream[:, 1:]}
    stream = task.sequence_batch(key, b, s)
    return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}


def host_batch(task: TokenTask, cfg: ArchConfig, shape: ShapeSpec, step: int,
               host_id: int, n_hosts: int) -> dict:
    """This host's slice of the global batch (batch axis split over hosts)."""
    full = global_batch(task, cfg, shape, step)
    per = shape.global_batch // n_hosts

    def sl(x):
        return jax.lax.dynamic_slice_in_dim(x, host_id * per, per, axis=0)

    return jax.tree.map(sl, full)
