"""Teacher-generated synthetic image classification (CNN faithful-repro path).

A fixed random teacher (conv stem + linear head) labels class-conditioned
Gaussian-blob images.  Labels are a real function of pixels, so (i) a student
CNN can learn them and (ii) quantizing the student genuinely degrades/recovers
accuracy — the property the SigmaQuant controller experiments need.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ImageTask:
    n_classes: int = 20
    img_size: int = 16
    channels: int = 3
    seed: int = 0
    noise: float = 0.35

    def _prototypes(self) -> jax.Array:
        """Spatially smooth class prototypes (low-res noise, upsampled) so the
        ±1-pixel jitter keeps images correlated with their class."""
        key = jax.random.key(self.seed ^ 0x1A6E)
        lo = self.img_size // 4
        coarse = jax.random.normal(key, (self.n_classes, lo, lo, self.channels))
        return 2.0 * jax.image.resize(
            coarse, (self.n_classes, self.img_size, self.img_size, self.channels),
            method="linear")

    def batch(self, key: jax.Array, batch: int) -> tuple[jax.Array, jax.Array]:
        """-> (images (B,H,W,C) float32, labels (B,) int32)."""
        protos = self._prototypes()
        kl, kn, kj = jax.random.split(key, 3)
        labels = jax.random.randint(kl, (batch,), 0, self.n_classes)
        base = protos[labels]
        noise = jax.random.normal(kn, base.shape) * self.noise
        # mild spatial jitter: roll each image by -1/0/+1 pixels
        shifts = jax.random.randint(kj, (batch, 2), -1, 2)
        imgs = jax.vmap(lambda im, sh: jnp.roll(im, sh, axis=(0, 1)))(base + noise, shifts)
        return imgs.astype(jnp.float32), labels.astype(jnp.int32)

    def batch_at(self, step: int, batch: int) -> tuple[jax.Array, jax.Array]:
        return self.batch(jax.random.fold_in(jax.random.key(self.seed), step), batch)

    def eval_set(self, n: int = 512) -> tuple[jax.Array, jax.Array]:
        """Fixed held-out evaluation set (step -1 namespace)."""
        return self.batch(jax.random.fold_in(jax.random.key(self.seed), 2**31 - 1), n)
