from .pipeline import TokenTask, PipelineState, host_batch, global_batch  # noqa: F401
from .images import ImageTask  # noqa: F401
